"""End-to-end driver: train the ~100M-parameter LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm_100m.py \
        [--steps 300] [--ckpt-dir /tmp/lm100m]

This is a thin wrapper over the production launcher
(``python -m repro.launch.train``) with the deliverable defaults:
100M params, synthetic LM data, checkpoints every 50 steps, auto-resume.
Add ``--dips`` for the importance-sampling pipeline or ``--compress 0.1``
for PPS gradient compression.  On this single-core CPU container expect
~10 s/step at the default batch geometry.
"""

import sys

sys.path.insert(0, "src")

from repro.launch.train import main  # noqa: E402

if __name__ == "__main__":
    if "--steps" not in " ".join(sys.argv):
        sys.argv += ["--steps", "300"]
    if "--batch" not in " ".join(sys.argv):
        sys.argv += ["--batch", "2", "--seq", "128"]
    if "--ckpt-dir" not in " ".join(sys.argv):
        sys.argv += ["--ckpt-dir", "/tmp/lm100m_ckpt", "--ckpt-every", "50"]
    main()
