"""Batched serving demo: prefill a batch of prompts, then greedy-decode.

    PYTHONPATH=src python examples/serve_decode.py [--arch qwen3-1.7b]

Uses the reduced (smoke) config of any assigned architecture so it runs on
CPU; the identical prefill/decode code paths are what the dry-run lowers
against the 256/512-chip meshes for the decode_32k / long_500k shapes.
"""

import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, get_smoke_config  # noqa: E402
from repro.models.model import build_model  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).replace(compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)

    B, T0 = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, T0)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), cfg.cdtype)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)), cfg.cdtype)

    max_len = T0 + args.new_tokens + 8
    state = model.init_state(B, max_len)
    t0 = time.perf_counter()
    logits, state = jax.jit(model.prefill)(params, batch, state)
    print(f"[{cfg.arch_id}] prefill {B}x{T0} in "
          f"{(time.perf_counter()-t0)*1e3:.0f} ms")

    decode = jax.jit(model.decode)
    tok = jnp.argmax(logits[:, -1:, : cfg.vocab_size], -1).astype(jnp.int32)
    seqs = [np.asarray(tok)]
    t0 = time.perf_counter()
    for _ in range(args.new_tokens):
        logits, state = decode(params, tok, state)
        tok = jnp.argmax(logits[:, -1:, : cfg.vocab_size], -1).astype(jnp.int32)
        seqs.append(np.asarray(tok))
    dt = time.perf_counter() - t0
    out = np.concatenate(seqs, axis=1)
    print(f"decoded {args.new_tokens} tokens/seq in {dt*1e3:.0f} ms "
          f"({dt/args.new_tokens*1e3:.1f} ms/token at batch {B})")
    for b in range(min(B, 2)):
        print(f"  seq {b}: {out[b].tolist()}")


if __name__ == "__main__":
    main()
