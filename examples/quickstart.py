"""Quickstart: the DIPS index in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Builds an index over a heavy-tailed weight set, runs dynamic updates that
would each cost O(n) under the subset-sampling reduction, and verifies the
empirical inclusion probabilities against the exact ones.
"""

import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core import DIPS, R_ODSS, max_abs_error  # noqa: E402


def main() -> None:
    rng = np.random.default_rng(0)
    n = 50_000
    weights = {i: float(w) for i, w in enumerate(rng.lognormal(0, 3, n))}

    print(f"== building DIPS over n={n} elements (c=0.8)")
    t0 = time.perf_counter()
    idx = DIPS(dict(weights), c=0.8, seed=42)
    print(f"   built in {time.perf_counter()-t0:.3f}s; "
          f"total weight {idx.total_weight:.3e}")

    print("== queries: each an independent Poisson pi-ps subset")
    for i in range(3):
        print(f"   query {i}: {idx.query()[:8]}")

    print("== the paper's motivating update: insert weight n^3")
    t0 = time.perf_counter()
    idx.insert("whale", float(n) ** 3)
    dt_dips = time.perf_counter() - t0
    print(f"   DIPS insert: {dt_dips*1e6:.1f} us "
          f"(every inclusion probability just changed!)")
    print(f"   P[whale] = {idx.inclusion_probability('whale'):.6f}")

    print("== the same update through the subset-sampling reduction (R-ODSS)")
    odss = R_ODSS(dict(weights), c=0.8, seed=42)
    t0 = time.perf_counter()
    odss.insert("whale", float(n) ** 3)
    dt_odss = time.perf_counter() - t0
    print(f"   R-ODSS insert: {dt_odss*1e6:.1f} us "
          f"({dt_odss/max(dt_dips,1e-9):.0f}x slower: full rebuild)")

    print("== churn: 1000 random weight changes (all O(1) on DIPS)")
    t0 = time.perf_counter()
    for _ in range(1000):
        k = int(rng.integers(n))
        idx.change_w(k, float(rng.lognormal(0, 3)))
    print(f"   {1e3*(time.perf_counter()-t0):.1f} ms total "
          f"({(time.perf_counter()-t0)*1e3:.1f} us/update)")

    print("== statistical check after churn (20k queries)")
    counts = {}
    R = 20_000
    for _ in range(R):
        for k in idx.query():
            counts[k] = counts.get(k, 0) + 1
    err = max_abs_error(idx.to_instance(), counts, R)
    print(f"   max |empirical - exact| inclusion probability: {err:.4f}")
    assert err < 0.02
    print("OK")


if __name__ == "__main__":
    main()
