"""Engine quickstart: swap sampling backends in three lines.

Every dynamic Poisson pi-ps sampler in the framework is constructed
through the ``repro.engine`` registry, so the *same* code drives the
paper-faithful host index, the batched JAX engines, and the fused Pallas
kernel -- pick one by name:

    from repro.engine import make_engine
    eng = make_engine("jax-bucketed", weights, c=1.0, seed=0)   # <- the swap
    ids, counts = eng.query_batch(jax.random.key(0), batch=1024)

Run:  PYTHONPATH=src python examples/engine_quickstart.py
"""

import jax
import numpy as np

from repro.engine import available_engines, make_engine


def main() -> None:
    rng = np.random.default_rng(0)
    weights = {i: float(w) for i, w in enumerate(rng.lognormal(0, 2, 1000))}

    print(f"{'engine':14s} {'kind':7s} E|X|   p(heaviest)  after change_w")
    heavy = max(weights, key=weights.get)
    for name in available_engines():
        eng = make_engine(name, dict(weights), c=1.0, seed=0)

        # batched query: 2000 independent PPS subsets in one call
        ids, counts = eng.query_batch(jax.random.key(0), batch=2000)
        p_heavy = eng.inclusion_probability(heavy)

        # dynamic updates -- O(1) on host-dips, buffered deltas on device;
        # every backend keeps the same logical instance
        eng.insert("fresh", 50.0)
        eng.change_w(heavy, weights[heavy] * 32.0)  # cross-bucket move
        eng.delete(0)

        print(f"{name:14s} {eng.kind:7s} {counts.mean():.2f}  "
              f"{p_heavy:.4f}       {eng.inclusion_probability(heavy):.4f}")

    # single-query form (host cost model), identical API
    eng = make_engine("host-dips", dict(weights), c=0.5, seed=0)
    print("one query:", eng.query(np.random.default_rng(1)))

    # multi-device pools: "jax-sharded" partitions slots across the mesh
    # (1-D slot mesh over every visible device -- on a laptop that is a
    # 1-device mesh, on a TPU pod it is the whole pod; run with
    # XLA_FLAGS=--xla_force_host_platform_device_count=4 to see 4 shards)
    eng = make_engine("jax-sharded", dict(weights), c=1.0, seed=0)
    ids, counts = eng.query_batch(jax.random.key(0), batch=512)
    layout = eng.mesh_layout()
    print(f"\njax-sharded: E|X|={counts.mean():.2f} over "
          f"{layout['num_shards']} shard(s) on axis {layout['axis']!r}")
    print(f"  devices:              {layout['devices']}")
    print(f"  live slots per shard: {layout['live_slots_per_shard']}")
    print(f"  size class (n,m,b):   {layout['size_class']}  "
          f"<- rebuilds inside this class never recompile")


if __name__ == "__main__":
    main()
