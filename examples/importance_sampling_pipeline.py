"""DIPS-driven importance-sampling training (the framework integration).

    PYTHONPATH=src python examples/importance_sampling_pipeline.py

Trains a small LM twice on a pool where 10% of documents are 'hard'
(different transition map): once with uniform sampling, once with the DIPS
loss-proportional pipeline.  After every step the trainer feeds per-example
losses back into the index -- each an O(1) ``change_w`` -- and the sampler
shifts toward the hard examples, which is visible both in the final sample
distribution and in the hard-pool loss.
"""

import sys
import time

import numpy as np

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs.base import ModelConfig  # noqa: E402
from repro.data import synthetic  # noqa: E402
from repro.data.pipeline import DIPSSamplingPipeline  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.train.loop import Trainer, TrainerConfig  # noqa: E402
from repro.train.optimizer import OptimizerConfig  # noqa: E402

TINY = ModelConfig(
    arch_id="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=256, vocab_size=256, tie_embeddings=True,
    compute_dtype="float32", remat="none", attn_chunk=0,
)

HARD_FRACTION = 0.1


def doc_fn(seed: int, doc_id: int, length: int, vocab: int) -> np.ndarray:
    """90% easy docs (shared map), 10% hard docs (a different map)."""
    if doc_id % 10 == 0:  # hard: second transition map
        rng = np.random.default_rng(np.random.SeedSequence([seed, doc_id, 7]))
        K = min(64, vocab)
        toks = np.empty(length, np.int32)
        toks[0] = rng.integers(K)
        noise = rng.random(length)
        jumps = rng.integers(0, K, length)
        for i in range(1, length):
            toks[i] = (toks[i - 1] * 13 + 5) % K if noise[i] < 0.8 else jumps[i]
        return toks
    return synthetic.synth_document(seed, doc_id, length, vocab)


def main() -> None:
    steps, batch, seq, pool = 80, 8, 64, 128
    model = build_model(TINY)
    opt = OptimizerConfig(lr=1e-2, warmup_steps=3, total_steps=steps)

    print("== run 1: DIPS importance-sampling pipeline")
    t = Trainer(model, opt, TrainerConfig(
        steps=steps, batch=batch, seq_len=seq, log_every=20,
        use_dips_pipeline=True, dips_pool=pool))
    t.pipeline._doc_fn = doc_fn
    t.pipeline.ema = 0.3  # fast weight adaptation for the short demo
    out = t.run(resume=False)
    w = t.pipeline.state_dict()["weights"]
    hard = w[::10]
    easy = np.delete(w, slice(0, None, 10))
    print(f"   final loss {out['log'][-1]['loss']:.3f}")
    print(f"   mean weight hard docs {hard.mean():.3f} vs easy {easy.mean():.3f} "
          f"(ratio {hard.mean()/easy.mean():.2f}x -> sampler chases hard examples)")
    print(f"   total PPS queries issued: {t.pipeline.query_count} "
          f"(each O(1); {t.pipeline.query_count/steps:.0f} per step)")

    print("== run 2: uniform baseline")
    t2 = Trainer(model, opt, TrainerConfig(
        steps=steps, batch=batch, seq_len=seq, log_every=20))
    out2 = t2.run(resume=False)
    print(f"   final loss {out2['log'][-1]['loss']:.3f}")
    print("done")


if __name__ == "__main__":
    main()
