"""Dynamic Influence Maximization on an evolving graph (paper Sec 5).

    PYTHONPATH=src python examples/dynamic_im.py [--nodes 20000]

Simulates a social network that keeps evolving while seeds are re-selected:
every round, a batch of edges churns (deleted + reinserted with new
weights) and a fresh seed set is computed from RR sets.  The per-vertex
sampling indexes absorb each edge update in O(1) with DIPS; the
subset-sampling backends rebuild the touched vertex's index.
"""

import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.applications.im import (  # noqa: E402
    DynamicWCGraph,
    influence_maximization,
    synthetic_powerlaw_edges,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=20_000)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--n-rr", type=int, default=1500)
    ap.add_argument("--churn", type=int, default=5000)
    args = ap.parse_args()

    edges = synthetic_powerlaw_edges(args.nodes, 4, "exponential", seed=0)
    print(f"graph: {args.nodes} nodes, {len(edges)} weighted edges (WC model)")
    rng = np.random.default_rng(1)

    for backend in ("DIPS", "R-ODSS"):
        g = DynamicWCGraph.from_edges(args.nodes, edges, backend=backend, seed=0)
        total_update = total_im = 0.0
        for r in range(args.rounds):
            # -- network evolution: churn edges with fresh weights
            picks = [edges[i] for i in rng.integers(0, len(edges), args.churn)]
            t0 = time.perf_counter()
            for u, v, w in picks:
                g.delete_edge(u, v)
                g.insert_edge(u, v, float(rng.exponential(1.0)) + 1e-12)
            dt_u = time.perf_counter() - t0
            total_update += dt_u
            # -- re-select seeds on the updated graph
            seeds, cov, dt_im = influence_maximization(g, args.k, args.n_rr)
            total_im += dt_im
            print(f"  [{backend}] round {r}: churn {args.churn*2} updates in "
                  f"{dt_u*1e3:7.1f} ms | IM {dt_im:5.2f}s "
                  f"coverage={cov:.3f} seeds[:5]={seeds[:5]}")
        print(f"  [{backend}] totals: updates {total_update*1e3:.1f} ms, "
              f"IM {total_im:.2f} s\n")


if __name__ == "__main__":
    main()
