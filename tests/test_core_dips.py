"""Core DIPS correctness: distributions, dynamics, invariants, edge cases."""

import math

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st
from hypothesis.stateful import RuleBasedStateMachine, initialize, invariant, rule

from repro.core import (
    ALL_METHODS,
    DIPS,
    BruteForcePPS,
    PPSInstance,
    R_BSS,
    R_HSS,
    R_ODSS,
    max_abs_error,
)
from repro.core.pps import any_success_probability, truncated_geometric
from repro.core.samplers import BoundedRatioSampler, DynamicWeightedArray


def empirical_counts(idx, repeats, rng):
    counts = {}
    for _ in range(repeats):
        for k in idx.query(rng):
            counts[k] = counts.get(k, 0) + 1
    return counts


# ------------------------- building blocks ------------------------------------

def test_dynamic_weighted_array_ops():
    arr = DynamicWeightedArray([("a", 1.0), ("b", 2.0), ("c", 3.0)])
    assert len(arr) == 3 and arr.total == 6.0
    arr.change_w("b", 5.0)
    assert arr.total == 9.0 and arr.weight("b") == 5.0
    w = arr.delete("a")
    assert w == 1.0 and len(arr) == 2 and "a" not in arr
    # swap-with-last kept positions consistent
    assert arr.weight("c") == 3.0 and arr.weight("b") == 5.0


def test_truncated_geometric_distribution(rng):
    p, t = 0.3, 6
    q = any_success_probability(p, t)
    counts = np.zeros(t)
    n = 40000
    for _ in range(n):
        g = truncated_geometric(rng, p, q)
        assert 0 <= g < t
        counts[g] += 1
    expect = np.array([p * (1 - p) ** i / q for i in range(t)])
    assert np.abs(counts / n - expect).max() < 0.01


def test_bounded_ratio_sampler_distribution(rng):
    # weights within ratio b=4 of wbar
    items = [(i, 1.0 + 3.0 * rng.random()) for i in range(20)]
    samp = BoundedRatioSampler(wbar=4.0, items=items)
    W = samp.total
    R = 30000
    counts = {}
    for _ in range(R):
        out = []
        samp.query_into(0.8, 0.9, rng, out)  # c=0.8, thinning 0.9
        for k in out:
            counts[k] = counts.get(k, 0) + 1
    for k, w in items:
        expect = 0.9 * 0.8 * w / W
        assert abs(counts.get(k, 0) / R - expect) < 0.015


# ------------------------- full index distribution ------------------------------

@pytest.mark.parametrize("method", ["DIPS", "R-HSS", "R-BSS", "R-ODSS", "BruteForce"])
@pytest.mark.parametrize("c", [1.0, 0.6])
def test_query_distribution(method, c, rng):
    items = {i: float(w) for i, w in enumerate(rng.lognormal(0, 3, 60))}
    cls = ALL_METHODS[method]
    kw = {"leaf_threshold": 4} if method == "DIPS" else {}
    idx = cls(dict(items), c=c, seed=7, **kw)
    R = 20000
    counts = empirical_counts(idx, R, rng)
    err = max_abs_error(PPSInstance(items, c=c), counts, R)
    assert err < 0.02, f"{method} max abs error {err}"


def test_dips_extreme_weight_insert(rng):
    """The paper's motivating case: insert weight n^3 shifts every prob."""
    n = 200
    idx = DIPS({i: float(i + 1) for i in range(n)}, seed=3, leaf_threshold=4)
    idx.insert("huge", float(n**3))
    assert abs(idx.inclusion_probability("huge") - n**3 / (n**3 + n * (n + 1) / 2)) < 1e-9
    R = 20000
    counts = empirical_counts(idx, R, rng)
    err = max_abs_error(idx.to_instance(), counts, R)
    assert err < 0.02
    # and remove it again
    idx.delete("huge")
    idx.check_invariants()


def test_dips_wide_dynamic_range(rng):
    weights = {0: 1e-12, 1: 1e-6, 2: 1.0, 3: 1e6, 4: 1e12, 5: 3.7e3, 6: 0.04}
    idx = DIPS(dict(weights), seed=1, leaf_threshold=2)
    idx.check_invariants()
    R = 30000
    counts = empirical_counts(idx, R, rng)
    err = max_abs_error(idx.to_instance(), counts, R)
    assert err < 0.02


def test_dips_zero_weights_and_transitions():
    idx = DIPS({"a": 0.0, "b": 2.0}, seed=0)
    assert idx.inclusion_probability("a") == 0.0
    idx.change_w("a", 3.0)        # zero -> positive
    idx.change_w("b", 0.0)        # positive -> zero
    assert idx.inclusion_probability("b") == 0.0
    assert abs(idx.inclusion_probability("a") - 1.0) < 1e-12
    idx.check_invariants()
    for _ in range(50):
        out = idx.query()
        assert "b" not in out


def test_dips_empty_and_single():
    idx = DIPS({}, seed=0)
    assert idx.query() == []
    idx.insert("x", 5.0)
    hits = sum("x" in idx.query() for _ in range(200))
    assert hits == 200  # c=1, single element => always sampled
    idx.delete("x")
    assert idx.query() == []


def test_dips_rebuild_on_doubling(rng):
    idx = DIPS({i: 1.0 + rng.random() for i in range(20)}, seed=0, leaf_threshold=4)
    for i in range(20, 100):  # force several rebuilds
        idx.insert(i, float(rng.lognormal(0, 2)))
        if i % 7 == 0:
            idx.check_invariants()
    for i in range(90):  # mass deletion -> halving rebuilds
        idx.delete(i)
        if i % 13 == 0:
            idx.check_invariants()
    idx.check_invariants()


def test_update_preserves_distribution(rng):
    idx = DIPS({i: float(rng.lognormal(0, 2) + 0.1) for i in range(40)},
               seed=5, leaf_threshold=4)
    for step in range(300):
        op = rng.integers(3)
        keys = list(range(200))
        present = [k for k in keys if k in idx]
        if op == 0 or len(present) < 10:
            k = int(rng.integers(200))
            if k not in idx:
                idx.insert(k, float(rng.lognormal(0, 4)))
        elif op == 1:
            idx.delete(present[rng.integers(len(present))])
        else:
            idx.change_w(present[rng.integers(len(present))],
                         float(rng.lognormal(0, 4)))
    idx.check_invariants()
    R = 20000
    counts = empirical_counts(idx, R, rng)
    assert max_abs_error(idx.to_instance(), counts, R) < 0.025


# ------------------------- hypothesis property tests -----------------------------

@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ws=st.lists(st.floats(1e-6, 1e6), min_size=1, max_size=40),
       c=st.floats(0.05, 1.0))
def test_total_weight_and_probs_consistent(ws, c):
    items = {i: w for i, w in enumerate(ws)}
    idx = DIPS(dict(items), c=c, seed=0, leaf_threshold=4)
    assert math.isclose(idx.total_weight, sum(ws), rel_tol=1e-9)
    s = sum(idx.inclusion_probability(k) for k in items)
    assert math.isclose(s, c, rel_tol=1e-9)
    idx.check_invariants()


class DIPSMachine(RuleBasedStateMachine):
    """Random op sequences preserve structural invariants + exact totals."""

    def __init__(self):
        super().__init__()
        self.model = {}
        self.idx = DIPS({}, seed=0, leaf_threshold=3, b=2)
        self.next_key = 0
        self.peak = 1.0

    @rule(w=st.floats(1e-9, 1e9))
    def insert(self, w):
        self.idx.insert(self.next_key, w)
        self.model[self.next_key] = w
        self.next_key += 1
        self.peak = max(self.peak, w)

    @rule(data=st.data(), w=st.floats(1e-9, 1e9))
    def change(self, data, w):
        if not self.model:
            return
        k = data.draw(st.sampled_from(sorted(self.model)))
        self.idx.change_w(k, w)
        self.model[k] = w
        self.peak = max(self.peak, w)

    @rule(data=st.data())
    def delete(self, data):
        if not self.model:
            return
        k = data.draw(st.sampled_from(sorted(self.model)))
        self.idx.delete(k)
        del self.model[k]

    @rule()
    def query(self):
        out = self.idx.query()
        assert len(set(out)) == len(out)  # a subset: no duplicates
        for k in out:
            assert k in self.model and self.model[k] > 0

    @invariant()
    def structure_ok(self):
        assert len(self.idx) == len(self.model)
        live = sum(w for w in self.model.values() if w > 0)
        # float-drift tolerance scales with the largest magnitude ever seen
        assert math.isclose(self.idx.total_weight, live,
                            rel_tol=1e-6, abs_tol=max(1e-9, 1e-10 * self.peak))
        self.idx.check_invariants()


TestDIPSMachine = DIPSMachine.TestCase
TestDIPSMachine.settings = settings(
    max_examples=20, stateful_step_count=30, deadline=None,
    suppress_health_check=[HealthCheck.too_slow])
