"""DIPS data pipeline + PPS gradient compression + integration loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DIPSSamplingPipeline, StaticPipeline
from repro.train.compression import (
    CompressionConfig,
    compress_grads,
    init_ef_state,
)
from repro.models.common import Param, unwrap


def test_pipeline_batch_shapes():
    p = DIPSSamplingPipeline(pool_size=64, seq_len=32, vocab=100, seed=0)
    b = p.batch(8)
    assert b["tokens"].shape == (8, 32) and b["labels"].shape == (8, 32)
    assert b["tokens"].dtype == np.int32
    assert len(set(b["example_ids"].tolist())) == 8  # distinct examples
    assert (b["tokens"] < 100).all() and (b["tokens"] >= 0).all()


def test_pipeline_sampling_follows_weights():
    p = DIPSSamplingPipeline(pool_size=50, seq_len=8, vocab=50, seed=1)
    p.ema = 0.0  # hard overwrite for the test
    # weight 49 for example 7 vs 49 others at 1.0 => P[7 in query] = 0.5
    p.update_weights(np.asarray([7]), np.asarray([49.0]))
    trials = 2000
    hits = sum(7 in p._index.query() for _ in range(trials))
    assert 0.44 < hits / trials < 0.56


def test_pipeline_weight_updates_are_o1():
    """change_w cost must not grow with pool size (paper's core claim)."""
    import time

    def upd_time(pool):
        p = DIPSSamplingPipeline(pool_size=pool, seq_len=8, vocab=50, seed=2)
        ids = np.arange(200) % pool
        losses = np.random.default_rng(0).random(200) * 10
        t0 = time.perf_counter()
        p.update_weights(ids, losses)
        return time.perf_counter() - t0

    t_small, t_big = upd_time(1000), upd_time(50000)
    assert t_big < t_small * 8, f"update cost grew: {t_small} -> {t_big}"


def test_pipeline_state_roundtrip():
    p = DIPSSamplingPipeline(pool_size=20, seq_len=8, vocab=50, seed=3)
    p.update_weights(np.asarray([1, 2, 3]), np.asarray([9.0, 5.0, 2.0]))
    state = p.state_dict()
    q = DIPSSamplingPipeline(pool_size=20, seq_len=8, vocab=50, seed=3)
    q.load_state_dict(state)
    np.testing.assert_allclose(q.state_dict()["weights"], state["weights"])


def test_static_pipeline_deterministic():
    p = StaticPipeline(batch=4, seq_len=16, vocab=64, seed=5)
    a, b = p.batch_at(3), p.batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = p.batch_at(4)
    assert not np.array_equal(a["tokens"], c["tokens"])


# ------------------------------ compression -----------------------------------

def grads_tree(seed=0):
    k = jax.random.key(seed)
    return {
        "big": Param(jax.random.normal(k, (128, 64)), ("embed", "ffn")),
        "small": Param(jnp.ones((16,)), ("embed",)),
    }


def test_compress_density_and_small_leaf_passthrough():
    cfg = CompressionConfig(density=0.2, min_leaf_size=1024)
    g = grads_tree()
    out, _, metrics = compress_grads(cfg, g, jnp.asarray(0), None)
    ov = unwrap(out)
    gv = unwrap(g)
    np.testing.assert_allclose(np.asarray(ov["small"]), np.asarray(gv["small"]))
    nz = float(jnp.mean(ov["big"] != 0))
    assert nz < 0.5  # sparsified
    assert 0.0 < float(metrics["compression_kept_frac"]) < 0.6


def test_compress_unbiased():
    cfg = CompressionConfig(density=0.25, min_leaf_size=16, error_feedback=False)
    g = grads_tree(1)
    acc = jnp.zeros_like(unwrap(g)["big"])
    K = 300
    for s in range(K):
        out, _, _ = compress_grads(cfg, g, jnp.asarray(s), None)
        acc = acc + unwrap(out)["big"]
    est = acc / K
    ref = unwrap(g)["big"]
    rel = float(jnp.linalg.norm(est - ref) / jnp.linalg.norm(ref))
    assert rel < 0.25


def test_error_feedback_carries_residual():
    cfg = CompressionConfig(density=0.1, min_leaf_size=16)
    g = grads_tree(2)
    ef = init_ef_state(g)
    out, ef2, _ = compress_grads(cfg, g, jnp.asarray(0), ef)
    # residual + output == original (per leaf)
    total = unwrap(out)["big"].astype(jnp.float32) + unwrap(ef2.residual)["big"]
    np.testing.assert_allclose(np.asarray(total), np.asarray(unwrap(g)["big"]),
                               rtol=1e-5, atol=1e-5)


def test_training_with_compression_converges():
    """Tiny model, 12 steps: compressed loss decreases like dense (coarse)."""
    from repro.launch.train import LM_100M
    from repro.models.model import build_model
    from repro.train.loop import Trainer, TrainerConfig
    from repro.train.optimizer import OptimizerConfig

    cfg = LM_100M.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab_size=256)

    def run(comp):
        t = Trainer(build_model(cfg),
                    OptimizerConfig(lr=1e-2, warmup_steps=0, total_steps=12),
                    TrainerConfig(steps=12, batch=2, seq_len=32, log_every=100,
                                  compression=comp))
        log = t.run(resume=False)["log"]
        return log[0]["loss"], log[-1]["loss"]

    first_d, last_d = run(None)
    first_c, last_c = run(CompressionConfig(density=0.3))
    assert last_d < first_d - 0.1
    assert last_c < first_c - 0.05  # still learns under 3.3x compression
