"""SamplerEngine subsystem: registry, host/device agreement, dynamics.

The load-bearing guarantees:
  * every registered backend maintains the same logical instance under
    interleaved insert/delete/change_w (including cross-bucket moves) --
    identical ``inclusion_probability`` after each op, no caller resync;
  * device marginals (``query_batch`` empirics) match ``marginal_probs``
    and host-DIPS empirical frequencies within statistical tolerance;
  * the padded (ids, counts) contract is uniform across backends.
"""

import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.jax_index import (
    bucketed_change_w,
    bucketed_change_w_batch,
    bucketed_sample,
    build_bucketed_index,
    marginal_probs,
)
from repro.engine import (
    BucketedJaxEngine,
    ShardedBucketedEngine,
    available_engines,
    engine_kind,
    get_spec,
    make_engine,
    size_class,
    spec_for,
)

ALL = available_engines()


def lognormal_items(n, seed=0, sigma=2.0):
    w = np.random.default_rng(seed).lognormal(0, sigma, n)
    return {i: float(x) for i, x in enumerate(w)}


# ------------------------------- registry -----------------------------------

def test_registry_exposes_all_backends():
    assert len(ALL) >= 4
    assert {"host-dips", "jax-flat", "jax-bucketed", "jax-sharded",
            "pallas-mask"} <= set(ALL)
    assert len(available_engines(kind="host")) >= 4
    assert len(available_engines(kind="device")) >= 4


def test_registry_aliases_resolve_legacy_names():
    for legacy, canonical in [("DIPS", "host-dips"), ("R-ODSS", "host-rodss"),
                              ("BruteForce", "host-brute")]:
        assert get_spec(legacy).name == canonical
    with pytest.raises(KeyError):
        get_spec("no-such-engine")


def test_make_engine_constructs_each_backend():
    items = lognormal_items(40)
    for name in ALL:
        e = make_engine(name, dict(items), c=0.9, seed=0)
        assert len(e) == 40
        assert e.kind == engine_kind(name)
        assert e.total_weight == pytest.approx(sum(items.values()), rel=1e-5)


# ------------------------- query_batch contract ------------------------------

@pytest.mark.parametrize("name", ALL)
def test_query_batch_padding_contract(name):
    items = lognormal_items(60, seed=3)
    e = make_engine(name, dict(items), c=0.8, seed=0)
    ids, counts = e.query_batch(jax.random.key(0), 40, cap=16)
    assert ids.shape[0] == 40 and counts.shape == (40,)
    for row, cnt in zip(ids, counts):
        assert np.all(row[:cnt] < e.pad_id)      # valid slots first
        assert np.all(row[cnt:] >= len(e))       # scatter-safe padding
    decoded = e.decode_batch(ids, counts)
    for ks, cnt in zip(decoded, counts):
        assert len(ks) == cnt
        assert all(k in e for k in ks)


@pytest.mark.parametrize("name", ALL)
def test_query_returns_keys(name):
    items = {("k", i): 1.0 + i for i in range(30)}  # non-integer keys
    e = make_engine(name, dict(items), c=1.0, seed=1)
    rng = np.random.default_rng(2)
    for _ in range(10):
        for k in e.query(rng):
            assert k in items


# --------------------- host/device statistical agreement ---------------------

def test_bucketed_query_batch_marginals_match_snapshot():
    """BucketedJaxEngine empirics match marginal_probs of its snapshot."""
    items = lognormal_items(400, seed=5, sigma=2.5)
    e = make_engine("jax-bucketed", dict(items), c=0.8, seed=0)
    B = 60_000
    ids, cnt = e.query_batch(jax.random.key(7), B, cap=64)
    counts = np.bincount(ids.ravel(), minlength=e.pad_id + 1)
    emp = counts[: len(items)] / B
    truth = e.marginals()[: len(items)]
    # the snapshot is padded to its size class: the live prefix carries
    # the exact marginals, the padded tail exactly 0
    snap = np.asarray(marginal_probs(e._dbi.index, 0.8))
    n_live = e._dbi.spec.n_live
    assert np.abs(truth[e._dbi._live_slots] - snap[:n_live]).max() < 1e-6
    assert np.all(snap[n_live:] == 0.0)
    assert np.abs(emp - truth).max() < 0.012
    assert float(cnt.mean()) == pytest.approx(0.8, abs=0.03)


def test_host_dips_empirical_frequencies_match_device():
    """HostDIPSEngine empirics agree with analytic + device marginals."""
    items = lognormal_items(50, seed=8)
    host = make_engine("host-dips", dict(items), c=0.9, seed=0)
    B = 30_000
    ids, cnts = host.query_batch(jax.random.key(3), B, cap=32)
    counts = np.bincount(ids.ravel(), minlength=host.pad_id + 1)
    emp = counts[: len(items)] / B
    W = sum(items.values())
    truth = np.asarray([min(1.0, 0.9 * items[i] / W) for i in range(len(items))])
    assert np.abs(emp - truth).max() < 0.012
    dev = make_engine("jax-bucketed", dict(items), c=0.9, seed=0)
    assert np.abs(dev.marginals()[: len(items)] - truth).max() < 1e-5


# ----------------------------- dynamic agreement -------------------------------

def _assert_probs_agree(engines, keys):
    ref_name, ref = engines[0]
    for k in keys:
        p_ref = ref.inclusion_probability(k)
        for name, e in engines[1:]:
            assert e.inclusion_probability(k) == pytest.approx(
                p_ref, rel=1e-6, abs=1e-12
            ), f"{name} disagrees with {ref_name} on key {k}"


def test_dynamic_ops_agree_across_all_engines():
    """Interleaved insert/delete/change_w (incl. cross-bucket moves):
    identical inclusion probabilities after every op, on every backend."""
    items = lognormal_items(48, seed=11)
    engines = [(n, make_engine(n, dict(items), c=1.0, seed=0)) for n in ALL]

    def apply_all(fn):
        for _, e in engines:
            fn(e)

    live = set(items)
    apply_all(lambda e: e.insert("new-a", 7.5));         live.add("new-a")
    _assert_probs_agree(engines, live)
    apply_all(lambda e: e.change_w(0, items[0] * 1.01))  # in-bucket nudge
    _assert_probs_agree(engines, live)
    apply_all(lambda e: e.change_w(1, items[1] * 64.0))  # cross-bucket move
    _assert_probs_agree(engines, live)
    apply_all(lambda e: e.change_w(1, items[1] / 64.0))  # and back down
    _assert_probs_agree(engines, live)
    apply_all(lambda e: e.delete(2));                    live.discard(2)
    _assert_probs_agree(engines, live)
    apply_all(lambda e: e.change_w(3, 0.0))              # weight -> zero
    _assert_probs_agree(engines, live)
    apply_all(lambda e: e.change_w(3, 5.0))              # zero -> weight
    _assert_probs_agree(engines, live)
    apply_all(lambda e: e.insert("new-b", 0.0));         live.add("new-b")
    _assert_probs_agree(engines, live)
    for _, e in engines:
        assert e.inclusion_probability("new-b") == 0.0
        assert len(e) == len(live)
        # snapshots capture the same logical instance
        assert e.snapshot().total_weight == pytest.approx(
            engines[0][1].snapshot().total_weight, rel=1e-6)


@pytest.mark.parametrize("name", ALL)
def test_change_w_unknown_key_leaves_state_untouched(name):
    e = make_engine(name, {0: 1.0, 1: 2.0}, c=1.0, seed=0)
    with pytest.raises(KeyError):
        e.change_w(99, 5.0)
    assert 99 not in e and len(e) == 2
    assert e.snapshot().total_weight == pytest.approx(3.0)


def test_pipeline_small_pool_never_blocks():
    from repro.data.pipeline import DIPSSamplingPipeline

    p = DIPSSamplingPipeline(pool_size=4, seq_len=8, vocab=20, seed=0)
    ids = p.sample_ids(16)  # more than the pool holds
    assert len(ids) == 4 and len(set(ids.tolist())) == 4


def test_bucketed_cross_bucket_change_without_resync():
    """The pre-engine API refused cross-bucket change_w (ok=False, caller
    resync).  The engine absorbs it: next query samples the new weight."""
    items = {i: 1.5 for i in range(64)}
    e = make_engine("jax-bucketed", dict(items), c=1.0, seed=0)
    e.change_w(0, 1.5 * 1000.0)  # far outside the original bucket
    B = 40_000
    ids, _ = e.query_batch(jax.random.key(1), B, cap=32)
    emp0 = float((ids == e._slots.slot(0)).sum()) / B
    truth0 = e.inclusion_probability(0)
    assert emp0 == pytest.approx(min(1.0, truth0), abs=0.02)


def test_bucketed_inbucket_deltas_flush_without_rebuild():
    """k in-bucket updates = one scatter, zero rebuilds."""
    # mid-bucket weights: bucket j of b=4 is (4^j, 4^{j+1}]; 2*4^j sits at
    # its center, and nudging toward 3*4^j is guaranteed to stay inside
    items = {i: 2.0 * 4.0 ** (i % 5) for i in range(256)}
    e: BucketedJaxEngine = make_engine("jax-bucketed", dict(items), seed=0)
    e.query_batch(jax.random.key(0), 4)
    before = e.rebuild_count
    for i in range(64):
        e.change_w(i, 3.0 * 4.0 ** (i % 5))  # same bucket by construction
    e.query_batch(jax.random.key(1), 4)  # flush applies one batched scatter
    assert e.rebuild_count == before
    assert np.abs(
        e.marginals()[: len(items)].sum() - 1.0
    ) < 1e-4  # c=1: marginals still sum to c


def test_bucketed_structural_churn_amortizes_rebuilds():
    e: BucketedJaxEngine = make_engine(
        "jax-bucketed", lognormal_items(400, seed=17), seed=0)
    for i in range(100):
        e.insert(("churn", i), 2.0)
    assert e.rebuild_count == 0       # burst marks, never rebuilds
    e.query_batch(jax.random.key(0), 4)
    assert e.rebuild_count == 1       # the whole burst costs ONE rebuild
    e.query_batch(jax.random.key(1), 4)
    assert e.rebuild_count == 1       # no structural pending: no rebuild


# ------------------------- batched device scatter ------------------------------

def test_bucketed_change_w_batch_matches_singles():
    w = np.asarray([1.5, 2.5, 3.0, 10.0, 40.0, 1.7])
    idx = build_bucketed_index(w, b=4)
    ids = np.asarray([0, 2, 4], np.int32)
    new = np.asarray([1.9, 3.5, 50.0], np.float32)
    got, ok_b = bucketed_change_w_batch(idx, ids, new)
    ref = idx
    for i, wn in zip(ids, new):
        ref, ok = bucketed_change_w(ref, i, wn)
        assert bool(ok)
    assert bool(np.all(np.asarray(ok_b)))
    np.testing.assert_allclose(
        np.asarray(got.sorted_weights), np.asarray(ref.sorted_weights))
    assert float(got.total) == pytest.approx(float(ref.total), rel=1e-6)


def test_bucketed_change_w_batch_refuses_out_of_bucket():
    w = np.asarray([1.5, 2.5, 10.0, 40.0])
    idx = build_bucketed_index(w, b=4)
    got, ok = bucketed_change_w_batch(
        idx, np.asarray([1, 2], np.int32), np.asarray([100.0, 12.0], np.float32))
    assert not bool(ok[0]) and bool(ok[1])
    assert float(got.total) == pytest.approx(w.sum() + 2.0, rel=1e-5)


# ------------------------ padded-shape (SnapshotSpec) semantics -----------------

def test_snapshot_spec_size_classes():
    s = spec_for(400, 11, 4)
    assert (s.n_pad, s.m_pad) == (512, 16)
    assert s.holds(512, 16) and not s.holds(513, 16) and not s.holds(1, 17)
    assert size_class(0, 64) == 64 and size_class(65, 64) == 128
    # two specs in the same class compile to the same program shapes
    assert spec_for(300, 9, 4).shape_class == s.shape_class


def test_padded_index_padding_probability_exactly_zero():
    w = np.random.default_rng(2).lognormal(0, 2, 100)
    idx = build_bucketed_index(w, b=4, n_pad=128, m_pad=16)
    assert idx.sorted_weights.shape == (128,) and idx.bucket_start.shape == (16,)
    probs = np.asarray(marginal_probs(idx, 0.9))
    assert np.all(probs[100:] == 0.0)          # padding: exactly 0
    assert probs.sum() == pytest.approx(0.9, rel=1e-4)
    # padded compact ids are never drawn, only live ids and the sentinel
    ids, _ = bucketed_sample(jax.random.key(0), idx, 0.9, batch=20_000, cap=32)
    ids = np.asarray(ids)
    assert not np.any((ids >= 100) & (ids < 128))
    assert float(np.abs(
        np.bincount(ids.ravel(), minlength=129)[:100] / 20_000 - probs[:100]
    ).max()) < 0.012


@pytest.mark.parametrize("name", ["jax-bucketed", "jax-sharded"])
def test_sentinels_never_leak_across_size_class_boundaries(name):
    """Grow the pool across a size-class boundary and shrink back: every
    returned id decodes to a live key, padding stays >= pad_id, at every
    class the engine visits."""
    e = make_engine(name, lognormal_items(60, seed=4), c=1.0, seed=0)

    def check():
        ids, counts = e.query_batch(jax.random.key(len(e)), 50, cap=16)
        for row, cnt in zip(ids, counts):
            assert np.all(row[:cnt] < e.pad_id)
            assert np.all(row[cnt:] >= len(e))
        for ks in e.decode_batch(ids, counts):
            assert all(k in e for k in ks)

    check()                                   # class n_pad=64
    for i in range(40):
        e.insert(("grow", i), 2.0 ** (i % 6))
    check()                                   # crossed into n_pad=128
    for i in range(40):
        e.delete(("grow", i))
    check()                                   # back to n_pad=64


@pytest.mark.parametrize("name", ["jax-bucketed", "jax-sharded"])
def test_churn_burst_within_size_class_zero_recompiles(name):
    """Acceptance: after warmup, a mixed burst of 1k updates + samples
    inside one size class adds NO compiled programs -- counter-verified
    against both the engine's own accounting and jax's jit cache."""
    # mid-bucket weights: bucket j of b=4 is (4^j, 4^{j+1}]; 2*4^j sits at
    # the center so the 3*4^j nudge below is in-bucket by construction
    items = {i: 2.0 * 4.0 ** (i % 5) for i in range(600)}
    e = make_engine(name, dict(items), c=1.0, seed=0)
    jit_cache = (
        _sharded_jit_cache_size if name == "jax-sharded"
        else bucketed_sample._cache_size
    )

    def spec():
        return e.spec if name == "jax-sharded" else e._dbi.spec

    def round_trip(r: int, structural: bool) -> None:
        # fixed-size in-bucket batch, optionally a structural pair, then
        # a sample: the op mix of a steady-state serving loop
        if structural:
            e.insert(("churn", r), 2.0 * 4.0 ** (r % 5))
            e.delete(("churn", r))
        for i in range(8):
            s = (r * 8 + i) % 600
            e.change_w(s, (2.0 if r % 2 else 3.0) * 4.0 ** (s % 5))
        e.query_batch(jax.random.key(r), 32, cap=16)

    round_trip(0, True)   # warmup: rebuild path + sample program
    round_trip(1, False)  # warmup: pure in-bucket scatter shape
    misses0, cache0, spec0 = e.compile_cache_misses, jit_cache(), spec()
    n_ops = 0
    r = 2
    while n_ops < 1000:
        round_trip(r, structural=bool(r % 2))
        n_ops += 10
        r += 1
    assert e.compile_cache_misses == misses0
    assert jit_cache() == cache0
    # the burst stayed inside one size class: identical padded shapes
    assert spec().shape_class == spec0.shape_class


def _sharded_jit_cache_size() -> int:
    from repro.engine.sharded import _sharded_sample

    return _sharded_sample._cache_size()


# ------------------------------ jax-sharded ----------------------------------

def test_sharded_marginals_match_host_on_one_device_mesh():
    """jax-sharded empirics agree with the analytic law and with
    jax-bucketed on the same instance (1-device mesh degenerate case)."""
    items = lognormal_items(300, seed=9, sigma=2.5)
    e = make_engine("jax-sharded", dict(items), c=0.8, seed=0)
    assert e.mesh_layout()["num_shards"] == len(jax.devices())
    B = 60_000
    ids, cnt = e.query_batch(jax.random.key(11), B, cap=64)
    emp = np.bincount(ids.ravel(), minlength=e.pad_id + 1)[:300] / B
    W = sum(items.values())
    truth = np.asarray([min(1.0, 0.8 * items[i] / W) for i in range(300)])
    assert np.abs(emp - truth).max() < 0.012
    assert float(cnt.mean()) == pytest.approx(0.8, abs=0.03)
    dev = make_engine("jax-bucketed", dict(items), c=0.8, seed=0)
    # bucketed marginals read the f32 device snapshot, sharded marginals
    # the f64 logical array: agreement up to f32 rounding
    assert np.abs(dev.marginals()[:300] - e.marginals()[:300]).max() < 1e-6


def test_sharded_empty_pool_returns_padding_only():
    e = make_engine("jax-sharded", {0: 1.0, 1: 2.0}, c=1.0, seed=0)
    e.delete(0), e.delete(1)
    ids, counts = e.query_batch(jax.random.key(0), 8, cap=4)
    assert np.all(counts == 0) and np.all(ids >= e.pad_id)
    e.insert("back", 3.0)  # sole live element, c=1 => sampled every time
    decoded = e.decode_batch(*e.query_batch(jax.random.key(1), 200, cap=4))
    assert sum(ks.count("back") for ks in decoded) > 150


def test_sharded_agrees_on_forced_multi_device_mesh():
    """Statistical agreement on a real 4-shard mesh (forced host devices;
    needs a fresh process because XLA device count is fixed at init)."""
    code = """
import numpy as np, jax
assert len(jax.devices()) == 4
from repro.engine import make_engine
w = np.random.default_rng(3).lognormal(0, 2.5, 500)
items = {i: float(x) for i, x in enumerate(w)}
e = make_engine("jax-sharded", dict(items), c=0.9, seed=0)
assert e.mesh_layout()["num_shards"] == 4
B = 60_000
ids, cnt = e.query_batch(jax.random.key(7), B, cap=64)
emp = np.bincount(ids.ravel(), minlength=e.pad_id + 1)[:500] / B
truth = e.marginals()[:500]
assert np.abs(emp - truth).max() < 0.012, np.abs(emp - truth).max()
e.insert("a", 123.0); e.delete(0); e.change_w(2, float(w[2]) * 100)
ids, cnt = e.query_batch(jax.random.key(9), B, cap=64)
emp_a = float((ids == e._slots.slot("a")).sum()) / B
assert abs(emp_a - e.inclusion_probability("a")) < 0.01
assert e.compile_cache_misses == 1   # churn stayed inside the size class
print("OK")
"""
    import os

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(root, "src"))
    proc = subprocess.run([sys.executable, "-c", code], env=env, cwd=root,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout
