"""Device-side batched samplers: marginals, independence, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (
    bucketed_change_w,
    bucketed_sample,
    build_bucketed_index,
    expected_sample_size,
    inclusion_probs,
    marginal_probs,
    pps_bernoulli_mask,
    pps_gradient_mask,
    pps_sample_indices,
)


def test_flat_mask_marginals(rng):
    w = rng.lognormal(0, 2, 300).astype(np.float32)
    m = pps_bernoulli_mask(jax.random.key(0), jnp.asarray(w), 0.7, batch=30000)
    emp = np.asarray(m).mean(0)
    truth = 0.7 * w / w.sum()
    assert np.abs(emp - truth).max() < 0.01


def test_flat_mask_rows_independent():
    w = jnp.asarray([1.0, 2.0, 3.0])
    m = np.asarray(pps_bernoulli_mask(jax.random.key(1), w, 1.0, batch=4000))
    # row correlation of first element across batch ~ 0
    col = m[:, 2].astype(float)
    r = np.corrcoef(col[:-1], col[1:])[0, 1]
    assert abs(r) < 0.05


def test_sample_indices_counts(rng):
    w = rng.lognormal(0, 1, 100).astype(np.float32)
    ids, cnt = pps_sample_indices(jax.random.key(2), jnp.asarray(w), 0.9,
                                  batch=5000, cap=16)
    ids = np.asarray(ids)
    cnt = np.asarray(cnt)
    assert float(cnt.mean()) == pytest.approx(0.9, abs=0.05)
    for b in range(50):  # padding contract
        assert np.all(ids[b, cnt[b]:] == 100)
        assert np.all(ids[b, :cnt[b]] < 100)


def test_expected_sample_size_equals_c(rng):
    w = jnp.asarray(rng.lognormal(0, 2, 64).astype(np.float32))
    assert float(expected_sample_size(w, 0.35)) == pytest.approx(0.35, rel=1e-5)


# ------------------------- bucketed (TPU-adapted) ------------------------------

def test_bucketed_marginals_match_flat(rng):
    w = rng.lognormal(0, 2.5, 500)
    idx = build_bucketed_index(w, b=4)
    B = 150000
    ids, cnt = bucketed_sample(jax.random.key(3), idx, 0.8, batch=B, cap=64)
    hits = np.zeros(len(w) + 1)
    np.add.at(hits, np.asarray(ids).ravel(), 1)
    emp = hits[: len(w)] / B
    truth = np.asarray(marginal_probs(idx, 0.8))
    assert np.abs(emp - truth).max() < 0.008
    assert float(np.asarray(cnt).mean()) == pytest.approx(0.8, abs=0.02)


def test_bucketed_no_duplicate_ids():
    w = np.linspace(1, 50, 40)
    idx = build_bucketed_index(w, b=2)
    ids, cnt = bucketed_sample(jax.random.key(4), idx, 1.0, batch=2000, cap=32)
    ids = np.asarray(ids)
    for b in range(200):
        row = ids[b][ids[b] < 40]
        assert len(np.unique(row)) == len(row)


def test_bucketed_change_w_in_bucket():
    w = np.asarray([1.5, 2.5, 10.0, 40.0])
    idx = build_bucketed_index(w, b=4)
    new, ok = bucketed_change_w(idx, jnp.int32(1), jnp.float32(3.9))
    assert bool(ok)
    assert float(new.total) == pytest.approx(w.sum() + 1.4, rel=1e-5)
    # out-of-bucket move is refused (host falls back to rebuild)
    new2, ok2 = bucketed_change_w(idx, jnp.int32(1), jnp.float32(100.0))
    assert not bool(ok2)
    assert float(new2.total) == pytest.approx(w.sum(), rel=1e-5)


# ------------------------- gradient compression ----------------------------------

def test_gradient_mask_unbiased(rng):
    g = jnp.asarray(rng.normal(size=2048), jnp.float32)
    acc = jnp.zeros_like(g)
    K = 600
    for i in range(K):
        out, keep = pps_gradient_mask(jax.random.key(i), g, 256.0)
        acc = acc + out
    est = acc / K
    rel = float(jnp.linalg.norm(est - g) / jnp.linalg.norm(g))
    assert rel < 0.2  # 1/sqrt(K) scaling of the unbiased estimator


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(n=st.integers(16, 512), frac=st.floats(0.05, 0.9))
def test_gradient_mask_density(n, frac):
    rng = np.random.default_rng(n)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    k = frac * n
    _, keep = pps_gradient_mask(jax.random.key(0), g, k)
    # E[kept] <= k (exactly k when no prob clips at 1)
    kept = float(jnp.sum(keep))
    assert kept <= n
    p = np.minimum(1.0, k * np.abs(np.asarray(g)) / np.abs(np.asarray(g)).sum())
    assert kept == pytest.approx(p.sum(), abs=4 * np.sqrt(p.sum()) + 1)


def test_gradient_mask_big_coords_always_kept():
    g = jnp.asarray([100.0, 0.001, 0.001, 0.001])
    out, keep = pps_gradient_mask(jax.random.key(0), g, 2.0)
    assert bool(keep[0])
    assert float(out[0]) == pytest.approx(100.0)  # p=1 -> no rescale
