"""End-to-end behaviour: training learns, DIPS pipeline integrates, serve path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import LM_100M
from repro.models.model import build_model
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import OptimizerConfig

TINY = LM_100M.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                       d_ff=256, vocab_size=512)


def test_training_loss_decreases():
    t = Trainer(build_model(TINY),
                OptimizerConfig(lr=1e-2, warmup_steps=3, total_steps=30),
                TrainerConfig(steps=30, batch=4, seq_len=64, log_every=100))
    out = t.run(resume=False)
    losses = [r["loss"] for r in out["log"]]
    assert losses[-1] < losses[0] - 0.5, f"no learning: {losses[0]} -> {losses[-1]}"


def test_training_with_dips_pipeline_learns_and_adapts():
    t = Trainer(build_model(TINY),
                OptimizerConfig(lr=1e-2, warmup_steps=3, total_steps=25),
                TrainerConfig(steps=25, batch=4, seq_len=64, log_every=100,
                              use_dips_pipeline=True, dips_pool=256))
    out = t.run(resume=False)
    losses = [r["loss"] for r in out["log"]]
    assert losses[-1] < losses[0] - 0.3
    # weights actually moved away from uniform
    w = t.pipeline.state_dict()["weights"]
    assert np.std(w) > 1e-3


def test_greedy_decode_roundtrip():
    """prefill + N greedy decode steps produce stable, finite tokens."""
    model = build_model(TINY.replace(compute_dtype="float32"))
    params = model.init(jax.random.key(0))
    B, T0 = 2, 12
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, 512, (B, T0)), jnp.int32)
    state = model.init_state(B, 64)
    logits, state = model.prefill(params, {"tokens": tokens}, state)
    seq = []
    tok = jnp.argmax(logits[:, -1:, :512], -1).astype(jnp.int32)
    decode = jax.jit(model.decode)
    for _ in range(10):
        seq.append(np.asarray(tok))
        logits, state = decode(params, tok, state)
        tok = jnp.argmax(logits[:, -1:, :512], -1).astype(jnp.int32)
        assert int(state.pos) <= 64
    seq = np.concatenate(seq, axis=1)
    assert seq.shape == (B, 10)
    assert (seq >= 0).all() and (seq < 512).all()


def test_metrics_are_finite_and_complete():
    t = Trainer(build_model(TINY),
                OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=3),
                TrainerConfig(steps=3, batch=2, seq_len=32, log_every=100))
    out = t.run(resume=False)
    m = out["metrics"]
    for key in ("loss", "accuracy", "grad_norm", "lr"):
        assert key in m and np.isfinite(m[key]), f"bad metric {key}: {m}"
