import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parent.parent
SRC = str(REPO / "src")
sys.path.insert(0, SRC)

# Property-based test modules need hypothesis (declared in
# requirements-dev.txt); skip -- don't error -- collection when the
# environment lacks it so the rest of the suite still runs.
try:
    import hypothesis  # noqa: F401
except ImportError:
    collect_ignore = [
        "test_core_dips.py",
        "test_jax_samplers.py",
        "test_table_lookup.py",
    ]


def run_subprocess(code: str, devices: int = 0, timeout: int = 600) -> str:
    """Run python code in a fresh process (own XLA device count)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"subprocess failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout


@pytest.fixture
def rng():
    return np.random.default_rng(0)
