"""Node-failure recovery, straggler detection, shard rebalancing."""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.train.straggler import ShardRebalancer, StragglerMonitor
from conftest import SRC, run_subprocess


def test_crash_and_resume_matches_uninterrupted(tmp_path):
    """kill -9 mid-run (os._exit in-step), restart, final params identical."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    script = """
import sys, jax, numpy as np
from repro.launch.train import LM_100M
from repro.models.model import build_model
from repro.models.common import unwrap
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import OptimizerConfig

mode, ckpt = sys.argv[1], sys.argv[2]
cfg = LM_100M.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab_size=512)
crash = 4 if mode == "crash" else None
t = Trainer(build_model(cfg),
            OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=6),
            TrainerConfig(steps=6, batch=2, seq_len=32, ckpt_dir=ckpt,
                          ckpt_every=2, log_every=100, crash_at_step=crash))
out = t.run(resume=True)
leaves = jax.tree.leaves(unwrap(out["state"].params))
print("FINGERPRINT", float(sum(np.abs(np.asarray(l)).sum() for l in leaves)))
"""
    sp = tmp_path / "driver.py"
    sp.write_text(script)

    # reference: uninterrupted run
    ref = subprocess.run([sys.executable, str(sp), "ok", str(tmp_path / "ref")],
                         env=env, capture_output=True, text=True, timeout=900)
    assert ref.returncode == 0, ref.stderr
    fp_ref = float(ref.stdout.split("FINGERPRINT")[1])

    # crashing run: exits with code 42 at step 4 (after ckpt at step 4)
    crash = subprocess.run([sys.executable, str(sp), "crash", str(tmp_path / "c")],
                           env=env, capture_output=True, text=True, timeout=900)
    assert crash.returncode == 42, f"expected injected crash, got {crash.returncode}"

    # restart with the same command: auto-resume from latest checkpoint
    resumed = subprocess.run([sys.executable, str(sp), "ok", str(tmp_path / "c")],
                             env=env, capture_output=True, text=True, timeout=900)
    assert resumed.returncode == 0, resumed.stderr
    assert "resumed from step" in resumed.stdout
    fp_res = float(resumed.stdout.split("FINGERPRINT")[1])
    assert fp_res == pytest.approx(fp_ref, rel=1e-6), (
        f"crash-resume diverged: {fp_res} vs {fp_ref}")


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(threshold=2.0, warmup_steps=3)
    for s in range(10):
        assert mon.record(s, 1.0) is None
    ev = mon.record(10, 3.5)
    assert ev is not None and ev.ratio == pytest.approx(3.5, rel=0.01)
    # outlier did not poison the baseline
    assert mon.ewma[0] == pytest.approx(1.0, rel=0.05)
    assert mon.record(11, 1.0) is None


def test_straggler_monitor_per_host():
    mon = StragglerMonitor(threshold=2.0, warmup_steps=2)
    for s in range(5):
        mon.record(s, 1.0, host=0)
        mon.record(s, 2.0, host=1)  # slow but *consistent* host: no event
    assert mon.events == []
    assert mon.record(5, 5.0, host=1) is not None  # 2.5x its own baseline


def test_shard_rebalancer_moves_work():
    rb = ShardRebalancer(n_hosts=4, n_shards=16)
    before = sorted(rb.assignment[1])
    moved = rb.rebalance(slow_host=1)
    assert moved in before
    assert len(rb.assignment[1]) == 3
    total = sum(len(v) for v in rb.assignment.values())
    assert total == 16  # no shard lost
    # repeated events keep draining but never to zero
    for _ in range(10):
        rb.rebalance(slow_host=1)
    assert len(rb.assignment[1]) >= 1
    # recovery earns shards back
    got = rb.restore(recovered_host=1)
    assert got is not None and len(rb.assignment[1]) >= 2
