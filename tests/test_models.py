"""Per-architecture smoke tests (reduced configs) + decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, get_smoke_config, shape_applicable
from repro.models.model import abstract_params, build_model, param_count


def make_batch(cfg, B, T, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), cfg.cdtype)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)), cfg.cdtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, 2, 16, rng)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert float(loss) > 0
    logits = model.forward(params, batch)
    T_total = 16 + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (2, T_total, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # one optimizer step decreases loss on the same batch (tiny lr)
    from repro.train.optimizer import OptimizerConfig
    from repro.train.step import init_train_state, make_train_step

    step = make_train_step(model, OptimizerConfig(lr=5e-3, warmup_steps=0,
                                                  total_steps=10))
    state = init_train_state(model, jax.random.key(0))
    state, m1 = jax.jit(step)(state, batch)
    state, m2 = jax.jit(step)(state, batch)
    assert float(m2["loss"]) < float(m1["loss"]) + 0.05


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_bulk_forward(arch, rng):
    """prefill(T0) + decode steps reproduce the bulk forward logits."""
    cfg = get_smoke_config(arch).replace(compute_dtype="float32",
                                          capacity_factor=4.0)
    # high capacity factor: token-choice MoE drops would (legitimately)
    # differ between bulk and incremental paths; equivalence needs no-drop
    if cfg.swa_window:
        cfg = cfg.replace(swa_window=8)  # exercise the ring buffer
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    B, T, T0 = 2, 12, 8
    batch = make_batch(cfg, B, T, rng)
    full_logits = model.forward(params, batch)
    full_logits = np.asarray(full_logits, np.float32)

    state = model.init_state(B, 32)
    pf = {k: (v[:, :T0] if k in ("tokens",) else v) for k, v in batch.items()
          if k != "labels"}
    logits, state = model.prefill(params, pf, state)
    offset = cfg.n_patches if cfg.family == "vlm" else 0
    np.testing.assert_allclose(
        np.asarray(logits[:, -1], np.float32),
        full_logits[:, offset + T0 - 1], rtol=2e-3, atol=2e-3)

    # teacher-forced decode of the remaining tokens
    for t in range(T0, T):
        tok = batch["tokens"][:, t : t + 1]
        logits, state = model.decode(params, tok, state)
        np.testing.assert_allclose(
            np.asarray(logits[:, -1], np.float32),
            full_logits[:, offset + t], rtol=2e-3, atol=2e-3,
            err_msg=f"{arch}: decode mismatch at position {t}")


def test_full_config_param_counts():
    expect = {
        "whisper-large-v3": (1.3e9, 1.8e9),
        "granite-moe-3b-a800m": (3.0e9, 3.6e9),
        "mixtral-8x22b": (1.30e11, 1.5e11),
        "hymba-1.5b": (1.1e9, 1.7e9),
        "xlstm-350m": (1.4e8, 4.5e8),
        "h2o-danube-3-4b": (3.5e9, 4.4e9),
        "deepseek-7b": (6.4e9, 7.4e9),
        "qwen3-1.7b": (1.5e9, 2.0e9),
        "gemma-2b": (2.2e9, 2.8e9),
        "internvl2-26b": (1.8e10, 2.2e10),
    }
    for arch in ARCH_IDS:
        model = build_model(get_config(arch))
        n = param_count(abstract_params(model))
        lo, hi = expect[arch]
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]B"


def test_long_context_applicability_flags():
    long = SHAPES["long_500k"]
    runs = {a: shape_applicable(get_config(a), long)[0] for a in ARCH_IDS}
    # SSM/hybrid/SWA archs must run the 500k decode; pure full-attention skip
    assert runs["xlstm-350m"] and runs["hymba-1.5b"]
    assert runs["mixtral-8x22b"] and runs["h2o-danube-3-4b"]  # SWA ring
    for a in ("deepseek-7b", "qwen3-1.7b", "gemma-2b", "internvl2-26b",
              "whisper-large-v3", "granite-moe-3b-a800m"):
        assert not runs[a], f"{a} should skip long_500k"


def test_moe_capacity_drops_are_bounded(rng):
    cfg = get_smoke_config("mixtral-8x22b").replace(capacity_factor=2.0,
                                                    compute_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, 2, 32, rng)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    assert float(metrics["aux_loss"]) >= 0.0
