"""Lemma 3.4 lookup table: paper Example 3.6, digit surgery, exactness."""

import collections
import math

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import DIPS, RoundedLookup
from repro.core.pps import PPSInstance, max_abs_error


def test_paper_example_3_6():
    """S={1,2}, wbar=(4,3), r=16 -> lambda=52, A_52 = [2x{}, 8x{1}, 3x{2}, 12x{1,2}]."""
    t = RoundedLookup([(1, 4.0), (2, 3.0)], radix=16)
    assert t.lam == 52
    assert t.Wbar == 7
    table = t._table_for_lambda()
    assert len(table) == (7 - 2) ** 2 == 25
    cnt = collections.Counter(table.tolist())
    assert cnt == {0b00: 2, 0b01: 8, 0b10: 3, 0b11: 12}


def test_example_3_5_rounding_is_corrected(rng):
    """Naive rounded sampling is biased (paper Example 3.5); the table +
    rejection recovers the exact probabilities."""
    weights = {"1": 2.9, "2": 7.0, "3": 3.1, "4": 4.7}
    t = RoundedLookup(list(weights.items()), radix=64)
    R = 150000
    counts = {}
    for _ in range(R):
        out = []
        t.query_into(1.0, rng, out)
        for k in out:
            counts[k] = counts.get(k, 0) + 1
    inst = PPSInstance(dict(weights), c=1.0)
    assert max_abs_error(inst, counts, R) < 0.01
    # element "1" specifically: naive rounding would give 3/19 = 0.158,
    # the correct value is 2.9/17.7 = 0.1638
    assert abs(counts["1"] / R - 2.9 / 17.7) < 0.01


def test_change_w_digit_surgery_matches_reencode():
    t = RoundedLookup([("a", 3.5), ("b", 9.2), ("c", 2.01)], radix=32)
    t.change_w("b", 4.4)
    t.change_w("a", 7.9)
    fresh = RoundedLookup([("a", 7.9), ("b", 4.4), ("c", 2.01)], radix=32)
    assert t.lam == fresh.lam
    assert t.Wbar == fresh.Wbar
    assert t.W == pytest.approx(fresh.W)


def test_factorized_equals_materialized():
    items = [("a", 2.2), ("b", 3.9), ("c", 1.5)]
    tm = RoundedLookup(items, radix=16, use_materialized=True)
    tf = RoundedLookup(items, radix=16, use_materialized=False)
    # identical subset distribution by construction
    dm = tm.subset_distribution()
    table = tm._table_for_lambda()
    counts = collections.Counter(table.tolist())
    size = len(table)
    for mask, p in dm.items():
        assert abs(counts.get(mask, 0) / size - p) < 1e-12
    # statistical agreement of full query path
    rng = np.random.default_rng(0)
    R = 60000
    out_m, out_f = {}, {}
    for _ in range(R):
        o = []
        tm.query_into(0.9, rng, o)
        for k in o:
            out_m[k] = out_m.get(k, 0) + 1
        o = []
        tf.query_into(0.9, rng, o)
        for k in o:
            out_f[k] = out_f.get(k, 0) + 1
    for k, _ in items:
        assert abs(out_m.get(k, 0) / R - out_f.get(k, 0) / R) < 0.012


def test_invalid_leaf_falls_back_exactly(rng):
    # single element and weight-1 boundaries violate Lemma 3.4 preconditions
    t = RoundedLookup([("only", 5.0)], radix=16)
    assert not t.is_valid()
    R = 5000
    hits = 0
    for _ in range(R):
        out = []
        t.query_into(1.0, rng, out)
        hits += len(out)
    assert hits == R  # p = 1


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ws=st.lists(st.floats(1.01, 30.0), min_size=2, max_size=5),
       c=st.floats(0.2, 1.0))
def test_lookup_distribution_property(ws, c):
    rng = np.random.default_rng(42)
    items = [(i, w) for i, w in enumerate(ws)]
    t = RoundedLookup(items, radix=64)
    R = 30000
    counts = {}
    for _ in range(R):
        out = []
        t.query_into(c, rng, out)
        for k in out:
            counts[k] = counts.get(k, 0) + 1
    inst = PPSInstance(dict(items), c=c)
    assert max_abs_error(inst, counts, R) < 0.025


def test_dips_with_table_leaf(rng):
    items = {i: float(rng.lognormal(2, 1) + 1.5) for i in range(80)}
    idx = DIPS(dict(items), b=2, leaf_threshold=4, leaf_backend="table", seed=9)
    R = 20000
    counts = {}
    for _ in range(R):
        for k in idx.query():
            counts[k] = counts.get(k, 0) + 1
    assert max_abs_error(idx.to_instance(), counts, R) < 0.02
