"""Checkpointing: atomicity, async, resume determinism, elastic restore."""

import json
import os
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager
from conftest import run_subprocess


def tiny_state(seed=0):
    k = jax.random.key(seed)
    return {
        "w": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(5, dtype=jnp.float32),
                   "step": jnp.asarray(3, jnp.int32)},
    }


def trees_equal(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(fa, fb))


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = tiny_state()
    mgr.save(10, state, extra_meta={"note": "hello"})
    restored, meta = mgr.restore(jax.eval_shape(lambda: state))
    assert trees_equal(state, restored)
    assert meta["step"] == 10 and meta["note"] == "hello"


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = tiny_state()
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.latest_step() == 4
    assert mgr.steps() == [3, 4]  # older ones garbage-collected


def test_atomicity_torn_write_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = tiny_state()
    mgr.save(5, state)
    # simulate a crash mid-save: torn tmp dir + step dir without meta
    (tmp_path / "step_9.tmp.12345").mkdir()
    (tmp_path / "step_7").mkdir()
    assert mgr.latest_step() == 5
    restored, meta = mgr.restore(jax.eval_shape(lambda: state))
    assert meta["step"] == 5


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = tiny_state()
    mgr.save_async(42, state)
    mgr.wait()
    restored, meta = mgr.restore(jax.eval_shape(lambda: state))
    assert meta["step"] == 42 and trees_equal(state, restored)


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    with pytest.raises(FileNotFoundError):
        mgr.restore(jax.eval_shape(tiny_state))


def test_structure_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, tiny_state())
    bad_template = {"only": jnp.zeros((2,))}
    with pytest.raises(ValueError):
        mgr.restore(jax.eval_shape(lambda: bad_template))


def test_resume_determinism(tmp_path):
    """3+3 steps with a restart == 6 uninterrupted steps (bit-identical)."""
    code = """
        import jax, numpy as np
        from repro.launch.train import LM_100M
        from repro.configs.base import ModelConfig
        from repro.models.model import build_model
        from repro.train.loop import Trainer, TrainerConfig
        from repro.train.optimizer import OptimizerConfig
        from repro.models.common import unwrap

        cfg = LM_100M.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                              d_ff=128, vocab_size=512)
        def run(steps, ckpt, resume):
            model = build_model(cfg)
            t = Trainer(model, OptimizerConfig(lr=1e-3, warmup_steps=0,
                                               total_steps=6),
                        TrainerConfig(steps=steps, batch=2, seq_len=32,
                                      ckpt_dir=ckpt, ckpt_every=3, log_every=100))
            return t.run(resume=resume)["state"]

        s_full = run(6, "{tmp}/full", resume=False)
        _ = run(3, "{tmp}/split", resume=False)
        s_resumed = run(6, "{tmp}/split", resume=True)
        fa = jax.tree.leaves(unwrap(s_full.params))
        fb = jax.tree.leaves(unwrap(s_resumed.params))
        for a, b in zip(fa, fb):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("resume-deterministic")
    """.replace("{tmp}", str(tmp_path))
    out = run_subprocess(code, timeout=900)
    assert "resume-deterministic" in out


def test_elastic_restore_across_device_counts(tmp_path):
    """Save on 1 device, restore sharded onto a (2,4) mesh: same values."""
    save_code = f"""
        import jax, numpy as np
        from repro.train.checkpoint import CheckpointManager
        state = {{"w": jax.random.normal(jax.random.key(0), (8, 16)),
                  "b": jax.random.normal(jax.random.key(1), (16,))}}
        CheckpointManager(r"{tmp_path}").save(7, state)
        np.save(r"{tmp_path}/expect_w.npy", np.asarray(state["w"]))
        print("saved")
    """
    assert "saved" in run_subprocess(save_code)
    restore_code = f"""
        import os
        import jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.train.checkpoint import CheckpointManager
        mesh = make_mesh((2, 4), ("data", "model"))
        template = {{"w": jax.ShapeDtypeStruct((8, 16), jnp.float32),
                     "b": jax.ShapeDtypeStruct((16,), jnp.float32)}}
        sh = {{"w": NamedSharding(mesh, P("data", "model")),
              "b": NamedSharding(mesh, P("model"))}}
        restored, meta = CheckpointManager(r"{tmp_path}").restore(template, shardings=sh)
        assert meta["step"] == 7
        assert len(restored["w"].sharding.device_set) == 8
        expect = np.load(r"{tmp_path}/expect_w.npy")
        np.testing.assert_array_equal(np.asarray(restored["w"]), expect)
        print("elastic-ok")
    """
    assert "elastic-ok" in run_subprocess(restore_code, devices=8)
