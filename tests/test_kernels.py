"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.pps_sample.ops import pps_sample_mask, pps_sample_mask_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


# ------------------------------ pps_sample ------------------------------------

@pytest.mark.parametrize("n,batch,c", [
    (128, 8, 1.0),
    (100, 64, 0.5),      # unaligned n -> padding path
    (513, 17, 0.25),     # both dims unaligned
    (2048, 256, 1.0),    # tile-exact
    (64, 300, 0.05),
])
def test_pps_kernel_bit_exact(n, batch, c, rng):
    w = jnp.asarray(rng.lognormal(0, 2, n), jnp.float32)
    key = jax.random.key(42)
    kern = pps_sample_mask(key, w, c, batch=batch, tb=8, tn=128)
    ref = pps_sample_mask_ref(key, w, c, batch=batch, tb=8, tn=128)
    assert kern.shape == (batch, n) and kern.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(ref))


@pytest.mark.parametrize("wdtype", [jnp.float32, jnp.bfloat16, jnp.float64])
def test_pps_kernel_weight_dtypes(wdtype, rng):
    w = jnp.asarray(rng.lognormal(0, 1, 256), wdtype)
    key = jax.random.key(0)
    kern = pps_sample_mask(key, w, 0.8, batch=64, tb=8, tn=128)
    ref = pps_sample_mask_ref(key, w, 0.8, batch=64, tb=8, tn=128)
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(ref))


def test_pps_kernel_statistics(rng):
    w = jnp.asarray(rng.lognormal(0, 2, 400), jnp.float32)
    mask = pps_sample_mask(jax.random.key(7), w, 0.9, batch=20000, tb=8, tn=128)
    emp = np.asarray(mask).mean(0)
    p = np.minimum(0.9 * np.asarray(w) / float(jnp.sum(w)), 1.0)
    assert np.abs(emp - p).max() < 0.012


def test_pps_kernel_zero_total():
    w = jnp.zeros(128, jnp.float32)
    mask = pps_sample_mask(jax.random.key(0), w, 1.0, batch=16, tb=8, tn=128)
    assert int(np.asarray(mask).sum()) == 0


# ------------------------------ flash attention --------------------------------

CASES = [
    # B, Hq, Hkv, Tq, Tk, D, causal, window
    (2, 4, 2, 128, 128, 64, True, 0),
    (1, 4, 1, 256, 256, 64, True, 64),    # MQA + sliding window
    (2, 2, 2, 100, 100, 32, True, 0),     # unaligned lengths
    (1, 8, 4, 1, 384, 64, True, 0),       # decode: single query
    (1, 4, 4, 64, 64, 128, False, 0),     # bidirectional (encoder)
    (1, 6, 2, 192, 320, 64, True, 0),     # Tq < Tk (chunked prefill tail)
    (1, 4, 4, 128, 128, 256, True, 0),    # gemma-style head_dim 256
]


@pytest.mark.parametrize("B,Hq,Hkv,Tq,Tk,D,causal,window", CASES)
def test_flash_matches_ref_f32(B, Hq, Hkv, Tq, Tk, D, causal, window):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, Tq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, Tk, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, Tk, D), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window, tq=128, tk=128)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,Hq,Hkv,Tq,Tk,D,causal,window", CASES[:4])
def test_flash_matches_ref_bf16(B, Hq, Hkv, Tq, Tk, D, causal, window):
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, Hq, Tq, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, Hkv, Tk, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, Hkv, Tk, D), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=causal, window=window, tq=128, tk=128)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=0.05, atol=0.05)


def test_flash_tiny_window_rows_without_keys():
    """window=1: each position attends only itself."""
    q = jax.random.normal(jax.random.key(0), (1, 2, 64, 32), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (1, 2, 64, 32), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (1, 2, 64, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=1, tq=64, tk=64)
    ref = attention_ref(q, k, v, causal=True, window=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
