"""Sharding rules, mesh construction, and small-mesh distributed execution."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.common import Param
from repro.sharding import (
    batch_shardings,
    decode_state_shardings,
    param_shardings,
    spec_for_axes,
)
from repro.sharding.context import activation_mesh, constrain
from conftest import run_subprocess


def fake_mesh(shape=(4, 2), axes=("data", "model")):
    # mesh over repeated CPU device refs: fine for spec resolution tests
    devs = np.asarray([jax.devices()[0]] * int(np.prod(shape))).reshape(shape)
    return Mesh(devs, axes)


def test_spec_for_axes_basic():
    mesh = fake_mesh()
    spec = spec_for_axes(mesh, ("layers", "embed", "ffn"), (8, 64, 128))
    assert spec == P(None, "data", "model")


def test_spec_for_axes_divisibility_fallback():
    mesh = fake_mesh((4, 4))
    # ffn=66 not divisible by model=4 -> replicated
    spec = spec_for_axes(mesh, ("layers", "embed", "ffn"), (8, 64, 66))
    assert spec == P(None, "data")
    # embed=30 not divisible by data=4 -> replicated
    spec = spec_for_axes(mesh, ("embed", "ffn"), (30, 128))
    assert spec == P(None, "model")


def test_spec_for_axes_no_axis_reuse():
    mesh = fake_mesh()
    # two dims both wanting "model": only the first gets it
    spec = spec_for_axes(mesh, ("ffn", "vocab"), (128, 256))
    assert spec == P("model")


def test_param_shardings_on_tagged_tree():
    mesh = fake_mesh()
    tree = {"w": Param(jax.ShapeDtypeStruct((64, 128), jnp.float32),
                       ("embed", "ffn")),
            "step": jnp.zeros((), jnp.int32)}
    sh = param_shardings(mesh, tree)
    assert sh["w"].spec == P("data", "model")
    assert sh["step"].spec == P()


def test_batch_shardings_divisibility():
    mesh = fake_mesh()
    specs = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
             "odd": jax.ShapeDtypeStruct((3, 16), jnp.int32)}
    sh = batch_shardings(mesh, specs)
    # jax versions differ on axis-name normalization: 'data' vs ('data',)
    assert sh["tokens"].spec in (
        P("data"), P("data", None), P(("data",)), P(("data",), None))
    assert sh["odd"].spec == P()


def test_decode_state_shardings_heuristic():
    mesh = fake_mesh()
    cache = jax.ShapeDtypeStruct((4, 8, 64, 5, 16), jnp.bfloat16)  # L,B,S,K,Dh
    sh = decode_state_shardings(mesh, {"k": cache}, batch=8)
    spec = sh["k"].spec
    assert spec[1] in ("data", ("data",))  # batch over data
    assert "model" in spec                 # largest divisible dim gets model


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 8))
    y = constrain(x, "__dp__", None)
    assert y is x


def test_constrain_drops_nondivisible():
    out = run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.sharding.context import activation_mesh, constrain
        mesh = make_mesh((4, 2), ("data", "model"))
        with activation_mesh(mesh):
            x = jnp.ones((6, 8))  # 6 % 4 != 0 -> dp dropped silently
            y = jax.jit(lambda a: constrain(a, "__dp__", "model"))(x)
            assert y.shape == x.shape
        print("constrain-ok")
    """, devices=8)
    assert "constrain-ok" in out


def test_production_mesh_shapes():
    out = run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        assert m1.axis_names == ("data", "model") and m1.devices.shape == (16, 16)
        assert m2.axis_names == ("pod", "data", "model")
        assert m2.devices.shape == (2, 16, 16)
        print("mesh-ok")
    """, devices=512)
    assert "mesh-ok" in out


def test_distributed_train_step_matches_single_device():
    """Same smoke model, 1 device vs 8-device (2,4) mesh: identical loss."""
    code_tpl = """
        import os
        {flags}
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models.model import build_model
        from repro.train.optimizer import OptimizerConfig
        from repro.train.step import init_train_state, make_train_step
        from repro.data.synthetic import batch_for_step
        cfg = get_smoke_config("qwen3-1.7b").replace(compute_dtype="float32")
        model = build_model(cfg)
        state = init_train_state(model, jax.random.key(0))
        step = make_train_step(model, OptimizerConfig(lr=1e-3, warmup_steps=0,
                                                      total_steps=4))
        raw = batch_for_step(0, 0, 8, 16, cfg.vocab_size)
        batch = {{k: jnp.asarray(v) for k, v in raw.items()}}
        {mesh_setup}
        for _ in range(3):
            state, metrics = jitted(state, batch)
        print("LOSS", float(metrics["loss"]))
    """
    single = run_subprocess(code_tpl.format(
        flags="",
        mesh_setup="jitted = jax.jit(step)"))
    multi = run_subprocess(code_tpl.format(
        flags='os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"',
        mesh_setup="""
        from repro.launch.mesh import make_mesh
        from repro.sharding import param_shardings, batch_shardings
        mesh = make_mesh((2, 4), ("data", "model"))
        state_sh = param_shardings(mesh, jax.eval_shape(lambda: state))
        batch_sh = batch_shardings(mesh, jax.eval_shape(lambda: batch))
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None))"""),
        devices=8)
    l1 = float(single.split("LOSS")[1])
    l8 = float(multi.split("LOSS")[1])
    # This gate failed at the seed with 2e-3 absolute: fp32 on CPU diverges
    # from reduction reorder alone (measured 5e-4 relative on the very
    # first forward pass, before any optimizer state exists, growing to
    # ~1.2e-3 relative by step 3).  Gate at 2.5x the observed drift.
    assert abs(l1 - l8) / max(l1, 1e-6) < 3e-3, f"single {l1} vs sharded {l8}"
