"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") -- the
"pod" axis carries pure data parallelism (gradient all-reduce is the only
collective crossing the pod boundary; FSDP parameter gathers stay inside a
pod, matching the ICI/DCN bandwidth split).

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} "
            "(dry-run must set --xla_force_host_platform_device_count)")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"mesh {shape} needs {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh() -> Mesh:
    """Whatever this host offers, as a 1-D data mesh (CPU tests/examples)."""
    devices = jax.devices()
    return Mesh(np.asarray(devices).reshape(len(devices), 1), ("data", "model"))
