"""Roofline-term extraction from compiled XLA artifacts.

The container is CPU-only, so nothing is *timed*: all three roofline terms
are derived from the compiled module (target: TPU v5e):

  compute  = HLO_FLOPs_per_chip / 197e12        (bf16 peak per chip)
  memory   = HLO_bytes_per_chip / 819e9         (HBM bandwidth)
  collective = collective_bytes_per_chip / 50e9 (ICI per-link)

``cost_analysis`` supplies flops / bytes of the partitioned per-device
module.  Collective bytes are NOT in cost_analysis: we parse the optimized
HLO text, sum result-shape sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, and apply ring-model
multipliers per op kind (documented below) with the replica-group size k:

  all-gather        bytes ~ S * (k-1)/k     (S = gathered result size)
  all-reduce        bytes ~ 2 * S * (k-1)/k
  reduce-scatter    bytes ~ S * (k-1)      (S = scattered result size)
  all-to-all        bytes ~ S * (k-1)/k
  collective-permute bytes ~ S
"""

from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12   # bf16 / chip (TPU v5e)
HBM_BW = 819e9        # bytes/s / chip
ICI_BW = 50e9         # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(line: str, op: str) -> int:
    """Sum of result-shape bytes on a collective def line (handles tuples)."""
    head = line.split(f" {op}(")[0]
    # take shapes after '=' only (result side)
    if "=" in head:
        head = head.split("=", 1)[1]
    total = 0
    for m in _SHAPE_RE.finditer(head):
        total += _shape_bytes(m.group(1), m.group(2))
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        # iota form [num_groups, group_size] (dims may be transposed by
        # <=[...] permutations; the product constraint disambiguates rarely,
        # so take the 2nd entry which is the group size in practice)
        return int(m.group(2))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Per-op-kind byte totals (ring-model, per participating device)."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        for op in _COLLECTIVES:
            token = f" {op}("
            if token in ls and not ls.startswith("//"):
                # skip -start/-done duplicates (count the -start only once)
                if f"{op}-done" in ls:
                    continue
                s = _result_bytes(ls, op)
                k = max(_group_size(ls), 1)
                if op == "all-gather":
                    moved = s * (k - 1) / max(k, 1)
                elif op == "all-reduce":
                    moved = 2 * s * (k - 1) / max(k, 1)
                elif op == "reduce-scatter":
                    moved = s * (k - 1)
                elif op == "all-to-all":
                    moved = s * (k - 1) / max(k, 1)
                else:  # collective-permute
                    moved = s
                out[op] += moved
                counts[op] += 1
                break
    total = sum(out.values())
    return {"per_op_bytes": out, "counts": counts, "total_bytes": total}


def roofline_terms(
    flops_per_chip: float,
    bytes_per_chip: float,
    collective_bytes_per_chip: float,
) -> Dict[str, float]:
    terms = {
        "compute_s": flops_per_chip / PEAK_FLOPS,
        "memory_s": bytes_per_chip / HBM_BW,
        "collective_s": collective_bytes_per_chip / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant
    bound = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["roofline_fraction_compute"] = (
        terms["compute_s"] / bound if bound > 0 else 0.0
    )
    return terms


def model_flops(n_params_active: int, tokens: int, kind: str) -> float:
    """Spec-mandated analytic FLOPs: 6*N*D train, 2*N*D inference."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens


def active_params(cfg, total_params: int) -> int:
    """MoE-aware active parameter count (router always active, top_k/E of
    expert FFN weights per token)."""
    if not cfg.is_moe:
        return total_params
    expert_w = 3 * cfg.n_layers * cfg.n_experts * cfg.d_model * cfg.d_ff
    inactive = expert_w * (1 - cfg.top_k / cfg.n_experts)
    return int(total_params - inactive)


def sharded_bytes(shape, dtype_bytes: int, spec, mesh) -> float:
    """Per-device bytes of an array under a PartitionSpec."""
    import numpy as np

    factor = 1
    for entry in spec:
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        for a in axes:
            factor *= mesh.shape[a]
    n = 1
    for d in shape:
        n *= int(d)
    return n * dtype_bytes / max(factor, 1)
