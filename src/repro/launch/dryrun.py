import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count at first init); 512 placeholder host devices back the production
meshes (16,16) and (2,16,16).

Per cell, two kinds of artifact are produced:

  1. FULL compile -- the production config (scanned layers, chunked
     attention) lowered and compiled against the mesh.  This is the
     feasibility proof: sharding coherence, compile success,
     memory_analysis (does it fit 16 GB/chip), wall times.

  2. COST PROBES -- XLA's cost_analysis counts while-loop bodies once, so
     scanned-layer numbers undercount by ~L.  We therefore compile two
     *probe* variants (2 and 4 layers -- 6/12 for xlstm's super-blocks --
     with the layer scan fully unrolled and dense attention) and
     extrapolate per-layer FLOPs / bytes / collective-bytes linearly to
     the full depth.  Probes keep time-recurrences (mLSTM/sLSTM/mamba)
     rolled; their per-step costs are added analytically (see
     ``_recurrence_correction``).  Probe numbers feed the roofline terms;
     the full compile proves the system runs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun \
      [--arch all] [--shape all] [--mesh single,multi] \
      [--out benchmarks/results/dryrun.json] [--no-probes]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from ..models.model import build_model, param_count
from ..models.common import is_param
from ..sharding import batch_shardings, decode_state_shardings, param_shardings
from ..sharding.context import activation_mesh
from ..train.optimizer import OptimizerConfig
from ..train.step import init_train_state, make_train_step
from .hlo_analysis import (
    active_params,
    collective_bytes,
    model_flops,
    roofline_terms,
    sharded_bytes,
)
from .mesh import make_production_mesh


def _tree_device_bytes(tree_abs, shardings) -> float:
    """Analytic per-device bytes of an abstract tree under its shardings."""
    total = 0.0
    leaves_a = jax.tree.leaves(tree_abs, is_leaf=is_param)
    leaves_s = jax.tree.leaves(shardings)
    flat_a = [p.value if is_param(p) else p for p in leaves_a]
    for a, s in zip(flat_a, leaves_s):
        if not hasattr(a, "shape"):
            continue
        total += sharded_bytes(a.shape, a.dtype.itemsize, s.spec, s.mesh)
    return total


def _build_lowered(cfg, shape, mesh):
    """Lower the step matching the shape kind; returns (lowered, extras)."""
    model = build_model(cfg)
    key = jax.random.key(0)
    extras = {}
    if shape.kind == "train":
        state_abs = jax.eval_shape(lambda k: init_train_state(model, k), key)
        state_sh = param_shardings(mesh, state_abs)
        batch_abs = model.input_specs(shape)
        batch_sh = batch_shardings(mesh, batch_abs)
        step = make_train_step(model, OptimizerConfig())
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh), donate_argnums=0)
        lowered = jitted.lower(state_abs, batch_abs)
        extras["state_bytes_per_device"] = _tree_device_bytes(state_abs, state_sh)
    else:
        params_abs = jax.eval_shape(model.init, key)
        params_sh = param_shardings(mesh, params_abs)
        batch_abs = model.input_specs(shape)
        batch_sh = batch_shardings(mesh, batch_abs)
        state_abs = jax.eval_shape(
            lambda: model.init_state(shape.global_batch, shape.seq_len))
        state_sh = decode_state_shardings(mesh, state_abs, shape.global_batch)
        if shape.kind == "prefill":
            jitted = jax.jit(model.prefill,
                             in_shardings=(params_sh, batch_sh, state_sh),
                             donate_argnums=2)
            lowered = jitted.lower(params_abs, batch_abs, state_abs)
        else:
            jitted = jax.jit(model.decode,
                             in_shardings=(params_sh, batch_sh["token"], state_sh),
                             donate_argnums=2)
            lowered = jitted.lower(params_abs, batch_abs["token"], state_abs)
        extras["state_bytes_per_device"] = _tree_device_bytes(state_abs, state_sh)
    return lowered, extras


def _cost_of(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    try:
        text = compiled.as_text()
    except Exception:
        text = ""
    coll = collective_bytes(text)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll["total_bytes"],
        "coll_detail": coll,
    }


def _probe_layers(cfg):
    if cfg.family == "ssm":
        p = max(cfg.slstm_every, 2)
        return p, 2 * p
    return 2, 4


def _probe_cfg(cfg, L):
    # Probes run in pure f32: the CPU backend cannot fuse bf16<->f32 dot
    # operand converts and would inflate "bytes accessed" by >2x with
    # artifact copies a TPU never materializes.  An all-f32 program has no
    # converts; its byte/collective counts are halved downstream to give
    # the bf16-equivalent estimate (flops are dtype-independent).
    over = dict(n_layers=L, scan_unroll=64, attn_chunk=0,
                param_dtype="float32", compute_dtype="float32")
    if cfg.family == "encdec":
        over["n_enc_layers"] = L
    return cfg.replace(**over)


def _recurrence_correction(cfg, shape) -> dict:
    """Analytic per-(T-1)-steps cost of rolled time recurrences (probes
    count a single step).  Returns global flops/bytes to add."""
    B, T = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    steps = max(T - 1, 0)
    train_mult = 4.0 if shape.kind == "train" else 1.0  # fwd + remat + ~2x bwd
    flops = bytes_ = 0.0
    if cfg.family == "ssm":
        H = cfg.n_heads
        Dh = cfg.d_model // H
        P = max(cfg.slstm_every, 2)
        n_m = cfg.n_layers * (P - 1) // P
        n_s = cfg.n_layers // P
        flops += steps * B * H * Dh * Dh * (8 * n_m + 8 * n_s)
        bytes_ += steps * B * H * Dh * Dh * 8 * (n_m + n_s)  # f32 C r/w
    if cfg.family == "hybrid":
        N = cfg.ssm_state
        d = cfg.d_model
        flops += steps * B * d * N * 10 * cfg.n_layers
        bytes_ += steps * B * d * N * 8 * cfg.n_layers
    return {"flops": flops * train_mult, "bytes": bytes_ * train_mult}


def run_cell(arch: str, shape_name: str, multi_pod: bool, probes: bool = True) -> dict:
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
    }
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    params_abs = jax.eval_shape(model.init, jax.random.key(0))
    n_params = param_count(params_abs)
    rec["n_params"] = n_params
    rec["n_chips"] = int(mesh.devices.size)

    with activation_mesh(mesh):
        # ---- 1) full compile (feasibility) --------------------------------
        t0 = time.time()
        lowered, extras = _build_lowered(cfg, shape, mesh)
        rec.update(extras)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                rec["memory_analysis"] = {
                    k: int(getattr(ma, k))
                    for k in ("argument_size_in_bytes", "output_size_in_bytes",
                              "temp_size_in_bytes")
                    if hasattr(ma, k)
                }
        except Exception as e:
            rec["memory_analysis_error"] = str(e)[:200]
        rec["full_cost_scanbody"] = {
            k: v for k, v in _cost_of(compiled).items() if k != "coll_detail"
        }

        # ---- 2) cost probes -------------------------------------------------
        flops = bytes_ = coll = None
        if probes:
            try:
                L2, L4 = _probe_layers(cfg)
                costs = {}
                for L in (L2, L4):
                    pl, _ = _build_lowered(_probe_cfg(cfg, L), shape, mesh)
                    costs[L] = _cost_of(pl.compile())
                rec["probe_costs"] = {
                    str(L): {k: v for k, v in c.items() if k != "coll_detail"}
                    for L, c in costs.items()
                }
                Lf = cfg.n_layers

                def extrap(key):
                    lo, hi = costs[L2][key], costs[L4][key]
                    slope = (hi - lo) / (L4 - L2)
                    return max(hi + slope * (Lf - L4), lo)

                corr = _recurrence_correction(cfg, shape)
                n_chips = rec["n_chips"]
                flops = extrap("flops") + corr["flops"] / n_chips
                # f32 probe -> bf16-equivalent traffic (see _probe_cfg)
                bytes_ = 0.5 * extrap("bytes") + corr["bytes"] / n_chips
                coll = 0.5 * extrap("coll")
                rec["recurrence_correction"] = corr
            except Exception as e:
                rec["probe_error"] = f"{type(e).__name__}: {str(e)[:300]}"
        if flops is None:  # fallback: scan-body numbers (undercount, flagged)
            c = rec["full_cost_scanbody"]
            flops, bytes_, coll = c["flops"], c["bytes"], c["coll"]
            rec["cost_source"] = "scanbody_fallback"
        else:
            rec["cost_source"] = "probe_extrapolated"

    rec["hlo_flops_per_device"] = flops
    rec["hlo_bytes_per_device"] = bytes_
    rec["collective_bytes_per_device"] = coll
    rec["roofline"] = roofline_terms(flops, bytes_, coll)

    tokens = (shape.global_batch * shape.seq_len
              if shape.kind != "decode" else shape.global_batch)
    n_active = active_params(cfg, n_params)
    rec["n_params_active"] = n_active
    mf = model_flops(n_active, tokens, shape.kind)
    rec["model_flops_total"] = mf
    rec["model_flops_per_device"] = mf / rec["n_chips"]
    rec["useful_flops_ratio"] = (
        rec["model_flops_per_device"] / flops if flops and flops > 0 else 0.0)
    rec["params_bytes_per_device"] = _tree_device_bytes(
        params_abs, param_shardings(mesh, params_abs))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="benchmarks/results/dryrun.json")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute existing cells")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = [m.strip() for m in args.mesh.split(",")]

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    records = []
    if out_path.exists():
        try:
            records = json.loads(out_path.read_text())
        except Exception:
            records = []
    if args.force:
        drop = {(a, s, "2x16x16" if m == "multi" else "16x16")
                for a in archs for s in shapes for m in meshes}
        records = [r for r in records
                   if (r["arch"], r["shape"], r["mesh"]) not in drop]
    done = {(r["arch"], r["shape"], r["mesh"]) for r in records}

    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                multi = mesh_kind == "multi"
                keyt = (arch, shape, "2x16x16" if multi else "16x16")
                if keyt in done:
                    continue
                t0 = time.time()
                try:
                    rec = run_cell(arch, shape, multi, probes=not args.no_probes)
                except Exception as e:
                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if multi else "16x16",
                        "status": "error",
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                rec["wall_s"] = round(time.time() - t0, 2)
                records.append(rec)
                out_path.write_text(json.dumps(records, indent=1))
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f"compute={r['compute_s']:.3e}s "
                             f"memory={r['memory_s']:.3e}s "
                             f"coll={r['collective_s']:.3e}s dom={r['dominant']} "
                             f"src={rec.get('cost_source','?')}")
                elif status == "error":
                    extra = rec["error"][:160]
                print(f"[{rec['wall_s']:7.1f}s] {arch:24s} {shape:12s} "
                      f"{rec['mesh']:8s} {status:7s} {extra}", flush=True)

    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors -> {out_path}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
