"""Training launcher.

Examples:
  # ~100M-param model, a few hundred steps on host CPU (deliverable (b)):
  PYTHONPATH=src python -m repro.launch.train --arch lm-100m --steps 300

  # any assigned architecture at smoke scale, with the DIPS pipeline:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
      --steps 50 --dips

  # fault-tolerance drill: crash at step 30, then rerun the same command
  # to auto-resume from the latest checkpoint:
  PYTHONPATH=src python -m repro.launch.train --arch lm-100m --steps 60 \
      --ckpt-dir /tmp/ck --crash-at 30
"""

from __future__ import annotations

import argparse

import jax

from ..configs import ARCH_IDS, get_config, get_smoke_config
from ..configs.base import ModelConfig
from ..models.model import build_model, param_count
from ..train.compression import CompressionConfig
from ..train.loop import Trainer, TrainerConfig
from ..train.optimizer import OptimizerConfig

# ~100M-parameter dense model for the end-to-end driver
LM_100M = ModelConfig(
    arch_id="lm-100m", family="dense",
    n_layers=8, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab_size=32000, tie_embeddings=True, attn_chunk=0,
    # CPU host runs: bf16 is emulated (slow) and remat only costs time
    compute_dtype="float32", remat="none",
)


def resolve_config(name: str, smoke: bool) -> ModelConfig:
    if name == "lm-100m":
        return LM_100M
    if smoke:
        return get_smoke_config(name)
    return get_config(name)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm-100m", help=f"lm-100m | {','.join(ARCH_IDS)}")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--dips", action="store_true", help="DIPS importance-sampling pipeline")
    ap.add_argument("--compress", type=float, default=0.0,
                    help="PPS gradient compression density (0 = off)")
    ap.add_argument("--crash-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = resolve_config(args.arch, args.smoke)
    model = build_model(cfg)
    n = param_count(jax.eval_shape(model.init, jax.random.key(0)))
    print(f"[launch] arch={cfg.arch_id} params={n/1e6:.1f}M "
          f"devices={jax.device_count()}")

    opt = OptimizerConfig(lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                          total_steps=args.steps)
    tcfg = TrainerConfig(
        steps=args.steps, batch=args.batch, seq_len=args.seq, seed=args.seed,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        use_dips_pipeline=args.dips,
        compression=(CompressionConfig(density=args.compress)
                     if args.compress > 0 else None),
        crash_at_step=args.crash_at,
    )
    trainer = Trainer(model, opt, tcfg)
    out = trainer.run()
    print(f"[launch] done: final loss {out['metrics'].get('loss'):.4f} "
          f"straggler_events={out['straggler_events']}")


if __name__ == "__main__":
    main()
