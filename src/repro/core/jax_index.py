"""TPU-native adaptation of the DIPS bucket hierarchy (hardware adaptation).

The paper's index is a pointer-rich host structure: hash maps, dynamic
arrays, per-bucket geometric jumps.  None of that maps onto a systolic
array.  What *does* transfer is the core insight -- partition by weight
ranges so that (a) work concentrates in the few significant buckets and
(b) per-bucket acceptance is at least ~1/b (bounded-ratio rejection,
Lemma 3.1) -- which becomes an output-sensitive *batched* sampler on TPU:

  1. Elements are bucketed by floor(log_b w) on device (sort once).
  2. For each of B independent queries, the candidate count of bucket j is
     Poisson(t_j * mu_j) with mu_j = -log(1 - pbar_j): by Poisson thinning,
     per-element candidate counts are independent Poisson(mu_j), so after
     accepting a candidate v with a_v = log(1-p_v)/log(1-pbar_j) <= 1 the
     inclusion events are *exactly* independent with P[v in X] = p_v
     (up to a 2^-24 probability clip; see tests for the statistical check).
  3. Expected candidates per query: sum_j t_j*mu_j ~ b*c = O(1) -- the same
     n -> "few significant ranges" reduction that gives DIPS its O(1)
     query, re-expressed as fixed-shape tensor ops (Poisson counts +
     gather + rejection) that jit, vmap and shard.

Updates: ``change_w`` within a bucket is a device scatter (O(1), batchable
via ``bucketed_change_w_at``/``bucketed_change_w_batch``); cross-bucket
moves, inserts and deletes are absorbed by
``repro.engine.dynamic_bucketed.DynamicBucketedIndex``, which marks them
host-side at O(1) and rebuilds the snapshot once at the next sample --
the Algorithm-4 idea of batching structural work into one rebuild.  See
DESIGN.md "Hardware adaptation".
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_P_CAP = 1.0 - 2.0**-24  # probability clip; keeps log1p finite


class BucketedIndex(NamedTuple):
    """Frozen device-side snapshot of the bucket decomposition."""

    sorted_weights: jax.Array  # (n,) weights sorted by bucket id
    sorted_ids: jax.Array      # (n,) original element ids, same order
    bucket_start: jax.Array    # (m,) offset of each bucket in sorted order
    bucket_count: jax.Array    # (m,) elements per bucket
    bucket_wbar: jax.Array     # (m,) b^{j+1} upper bound per bucket
    bucket_lo: jax.Array       # (m,) b^j lower bound (change_w validity)
    total: jax.Array           # () sum of weights
    b: int


def bucket_ids(w: np.ndarray, b: int) -> np.ndarray:
    """j with b^j < w <= b^{j+1} (floor-log, boundary-repaired).

    THE host-side bucket formula: the dynamic layer's in-bucket fast path
    classifies against this, and it must match the b^j/b^{j+1} bounds the
    device ok-check derives from it -- keep a single copy.
    """
    j = np.floor(np.log(w) / np.log(b)).astype(np.int64)
    return np.where(w <= np.power(float(b), j), j - 1, j)


def build_bucketed_index(
    weights: np.ndarray | jax.Array,
    b: int = 4,
    *,
    n_pad: int | None = None,
    m_pad: int | None = None,
    j: np.ndarray | None = None,
) -> BucketedIndex:
    """Host-side build (sort by bucket), O(n log n) once.

    ``n_pad``/``m_pad`` pad the element and bucket axes to a static shape
    (size-class padding, see ``repro.engine.spec``): padded element slots
    get weight 0 and compact ids ``n..n_pad-1``; padded buckets get count
    0 (zero Poisson candidate rate -- a padded slot can never be drawn)
    with positive repeated bounds so downstream ratios stay finite, and
    ``bucket_start = n`` so ``searchsorted`` bucket lookups of live
    positions are unaffected.  Inclusion probabilities of padded slots
    are exactly 0; ``total`` is the true (unpadded) sum.

    ``j`` lets callers that already classified the weights (to size their
    pad classes) pass the ``bucket_ids(weights, b)`` result instead of
    paying the O(n) log pass twice.
    """
    w = np.asarray(weights, dtype=np.float64)
    if np.any(w <= 0):
        raise ValueError("BucketedIndex requires strictly positive weights")
    n = w.size
    if j is None:
        j = bucket_ids(w, b)
    order = np.argsort(j, kind="stable")
    js = j[order]
    uniq, start, count = np.unique(js, return_index=True, return_counts=True)
    m = uniq.size
    if n_pad is not None and n_pad < n:
        raise ValueError(f"n_pad={n_pad} < live size {n}")
    if m_pad is not None and m_pad < m:
        raise ValueError(f"m_pad={m_pad} < bucket count {m}")
    n_pad = n if n_pad is None else int(n_pad)
    m_pad = m if m_pad is None else int(m_pad)

    sw = np.zeros(n_pad, np.float64)
    sw[:n] = w[order]
    sid = np.arange(n_pad, dtype=np.int64)
    sid[:n] = order
    bstart = np.full(m_pad, n, np.int64)
    bstart[:m] = start
    bcount = np.zeros(m_pad, np.int64)
    bcount[:m] = count
    last_hi = float(b) ** (uniq[-1] + 1) if m else 1.0
    bwbar = np.full(m_pad, last_hi, np.float64)
    bwbar[:m] = np.power(float(b), uniq + 1)
    blo = np.full(m_pad, last_hi, np.float64)
    blo[:m] = np.power(float(b), uniq)
    return BucketedIndex(
        sorted_weights=jnp.asarray(sw, dtype=jnp.float32),
        sorted_ids=jnp.asarray(sid, dtype=jnp.int32),
        bucket_start=jnp.asarray(bstart, dtype=jnp.int32),
        bucket_count=jnp.asarray(bcount, dtype=jnp.int32),
        bucket_wbar=jnp.asarray(bwbar, dtype=jnp.float32),
        bucket_lo=jnp.asarray(blo, dtype=jnp.float32),
        total=jnp.asarray(w.sum(), dtype=jnp.float32),
        b=b,
    )


@functools.partial(jax.jit, static_argnames=("batch", "cap"))
def bucketed_sample(
    key: jax.Array,
    index: BucketedIndex,
    c: float = 1.0,
    *,
    batch: int = 1,
    cap: int = 64,
) -> Tuple[jax.Array, jax.Array]:
    """Draw ``batch`` independent PPS subsets; returns (ids[B, cap], count[B]).

    Entries beyond ``count`` hold n (scatter-safe sentinel).  ``cap`` bounds
    the candidates examined per query; expected candidates ~ b*c, so any
    cap >> b*c makes truncation astronomically rare (surfaced via count).
    """
    m = index.bucket_start.shape[0]
    n = index.sorted_ids.shape[0]
    pbar = jnp.minimum(c * index.bucket_wbar / index.total, _P_CAP)  # (m,)
    mu = -jnp.log1p(-pbar)  # per-element candidate rate
    kc, kp, ka = jax.random.split(key, 3)

    # 1) Poissonized candidate counts per (query, bucket).
    lam = index.bucket_count.astype(jnp.float32) * mu  # (m,)
    counts = jax.random.poisson(kc, jnp.broadcast_to(lam, (batch, m))).astype(jnp.int32)
    counts = jnp.minimum(counts, cap)

    # 2) Assign the `cap` candidate slots to buckets by cumulative counts.
    cum = jnp.cumsum(counts, axis=1)  # (B, m)
    slot = jnp.arange(cap)[None, :]
    bucket_for_slot = jnp.sum(slot >= cum[:, :, None], axis=1)  # (B, cap) in [0, m]
    valid = slot < cum[:, -1:]
    bfs = jnp.minimum(bucket_for_slot, m - 1)

    # 3) Uniform position inside the bucket (iid => Poisson thinning).
    t_j = index.bucket_count[bfs]
    u_pos = jax.random.uniform(kp, (batch, cap))
    pos = index.bucket_start[bfs] + jnp.minimum((u_pos * t_j).astype(jnp.int32), t_j - 1)
    w_cand = index.sorted_weights[pos]
    ids_cand = index.sorted_ids[pos]

    # 4) Thinning that makes marginals exact: accept with
    #    a_v = log(1-p_v)/log(1-pbar_j)  (in (0, 1] since p_v <= pbar_j).
    p_target = jnp.minimum(c * w_cand / index.total, _P_CAP)
    a = jnp.log1p(-p_target) / (-mu[bfs])  # both factors negative => a > 0
    accept = valid & (jax.random.uniform(ka, (batch, cap)) < a)

    # 5) De-duplicate (an element may appear as several candidates) and
    #    compact left; pad with n.
    ids_masked = jnp.where(accept, ids_cand, n)
    ids_sorted = jnp.sort(ids_masked, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((batch, 1), bool), ids_sorted[:, 1:] == ids_sorted[:, :-1]], axis=1
    )
    ids_unique = jnp.where(dup, n, ids_sorted)
    ids_final = jnp.sort(ids_unique, axis=1)
    cnt = jnp.sum(ids_final < n, axis=1).astype(jnp.int32)
    return ids_final.astype(jnp.int32), cnt


@jax.jit
def bucketed_change_w(
    index: BucketedIndex, element_id: jax.Array, w_new: jax.Array
) -> Tuple[BucketedIndex, jax.Array]:
    """In-bucket weight update as a device scatter (O(1) per update).

    Returns (new_index, ok); ``ok`` is False when the new weight leaves the
    element's bucket range, in which case the caller must resync/rebuild
    (host wrapper: same amortized-doubling rule as Algorithm 4).
    """
    pos = jnp.argmax(index.sorted_ids == element_id)
    old = index.sorted_weights[pos]
    bucket = jnp.sum(index.bucket_start <= pos) - 1
    ok = (w_new > index.bucket_lo[bucket]) & (w_new <= index.bucket_wbar[bucket])
    new_w = jnp.where(ok, w_new, old)
    return (
        index._replace(
            sorted_weights=index.sorted_weights.at[pos].set(new_w),
            total=index.total + (new_w - old),
        ),
        ok,
    )


@jax.jit
def bucketed_change_w_at(
    index: BucketedIndex, pos: jax.Array, w_new: jax.Array
) -> Tuple[BucketedIndex, jax.Array]:
    """k in-bucket weight updates at known sorted positions: ONE O(k)
    scatter (plus an O(k log m) bucket lookup for the validity check).

    ``pos`` (k,) int32 must be distinct sorted-order positions
    (last-write-wins scatter plus a summed total would otherwise
    disagree); ``w_new`` (k,) f32.  Returns (new_index, ok[k]); entries
    whose new weight leaves the bucket range are refused individually
    (weight kept, ok=False) so the caller can route just those through
    the structural rebuild path.
    """
    old = index.sorted_weights[pos]
    bucket = jnp.searchsorted(index.bucket_start, pos, side="right") - 1
    ok = (w_new > index.bucket_lo[bucket]) & (w_new <= index.bucket_wbar[bucket])
    eff = jnp.where(ok, w_new, old)
    return (
        index._replace(
            sorted_weights=index.sorted_weights.at[pos].set(eff),
            total=index.total + jnp.sum(eff - old),
        ),
        ok,
    )


@jax.jit
def bucketed_change_w_batch(
    index: BucketedIndex, element_ids: jax.Array, w_new: jax.Array
) -> Tuple[BucketedIndex, jax.Array]:
    """Like ``bucketed_change_w_at`` but addressed by element id: inverts
    the sort permutation on the fly (O(n)).  Callers that hold a cached
    inverse permutation (``DynamicBucketedIndex``) should use the O(k)
    positional form instead.  ``element_ids`` must be distinct.
    """
    n = index.sorted_ids.shape[0]
    inv = jnp.zeros(n, jnp.int32).at[index.sorted_ids].set(
        jnp.arange(n, dtype=jnp.int32)
    )
    return bucketed_change_w_at(index, inv[element_ids], w_new)


def marginal_probs(index: BucketedIndex, c: float = 1.0) -> jax.Array:
    """Exact per-element inclusion probability in original id order."""
    p_sorted = c * index.sorted_weights / index.total
    n = index.sorted_ids.shape[0]
    out = jnp.zeros(n, dtype=p_sorted.dtype)
    return out.at[index.sorted_ids].set(p_sorted)
