"""Device-side batched Poisson pi-ps sampling in JAX.

The host-side ``DIPS`` index answers *one* query in O(1); accelerators are
instead asked for *batches* of independent queries (e.g. one subset per
training example, or thousands of RR-set expansions per influence-
maximization round).  This module provides the jit-compatible batched
samplers used across the framework:

  * ``pps_bernoulli_mask``   -- flat sampler: (B, n) boolean inclusion mask.
    Work Theta(B*n); bandwidth-bound.  The Pallas kernel
    ``repro.kernels.pps_sample`` fuses RNG + threshold so the mask is the
    only HBM traffic (see kernels/pps_sample/ops.py).
  * ``pps_sample_indices``   -- output-sensitive sampler returning padded
    index lists; ``jax_index.bucketed_sample`` over a ``BucketedIndex``
    achieves expected work Theta(B * c) via the bucket reduction.
  * ``pps_gradient_mask``    -- unbiased sparsification operator used by
    the PPS gradient-compression hook (importance ~ |g|): element kept with
    p_v = min(1, k*|g_v|/sum|g|) and scaled by 1/p_v.

All functions are pure, take explicit PRNG keys, and are safe under jit,
vmap, and shard_map (keys must be pre-split per shard).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def inclusion_probs(weights: jax.Array, c: float | jax.Array = 1.0) -> jax.Array:
    """p_v = c * w_v / W with a zero-total guard."""
    w = jnp.asarray(weights)
    total = jnp.sum(w)
    return jnp.where(total > 0, c * w / jnp.maximum(total, 1e-38), 0.0)


@functools.partial(jax.jit, static_argnames=("batch",))
def pps_bernoulli_mask(
    key: jax.Array, weights: jax.Array, c: float | jax.Array = 1.0, *, batch: int = 1
) -> jax.Array:
    """(batch, n) bool mask; mask[b, v] ~ Bernoulli(c*w_v/W) independently."""
    p = inclusion_probs(weights, c)
    u = jax.random.uniform(key, (batch, p.shape[0]), dtype=jnp.float32)
    return u < p[None, :]


@functools.partial(jax.jit, static_argnames=("cap",))
def mask_to_indices(
    mask: jax.Array, *, cap: int = 64
) -> Tuple[jax.Array, jax.Array]:
    """Compact a (B, n) bool mask to padded (idx[B, <=cap], count[B]).

    THE padding contract shared by every sampler that emits index lists
    (flat, bucketed, Pallas engines): hit positions first in stable order,
    entries beyond ``count`` set to n (an out-of-range sentinel usable
    directly for segment-sum style scatters), overflow beyond ``cap``
    truncated deterministically from the left.
    """
    n = mask.shape[1]
    order = jnp.argsort(~mask, axis=1, stable=True)  # hits first
    count = jnp.sum(mask, axis=1).astype(jnp.int32)
    idx = jnp.where(jnp.arange(n)[None, :] < count[:, None], order, n)
    return idx[:, :cap].astype(jnp.int32), jnp.minimum(count, cap)


@functools.partial(jax.jit, static_argnames=("batch", "cap"))
def pps_sample_indices(
    key: jax.Array,
    weights: jax.Array,
    c: float | jax.Array = 1.0,
    *,
    batch: int = 1,
    cap: int = 64,
) -> Tuple[jax.Array, jax.Array]:
    """Padded index-list form: (idx[B, cap] int32, count[B] int32).

    See ``mask_to_indices`` for the padding/truncation contract.
    """
    mask = pps_bernoulli_mask(key, weights, c, batch=batch)
    return mask_to_indices(mask, cap=cap)


@jax.jit
def pps_gradient_mask(
    key: jax.Array, grads: jax.Array, k: float | jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Poisson pi-ps gradient sparsification (unbiased).

    Keeps coordinate v with probability p_v = min(1, k*|g_v|/sum|g|) and
    rescales survivors by 1/p_v, so E[out] = grads exactly; expected number
    of survivors is <= k.  Returns (sparsified_grads, keep_mask).
    """
    g = grads.reshape(-1)
    mag = jnp.abs(g)
    total = jnp.sum(mag)
    p = jnp.minimum(1.0, k * mag / jnp.maximum(total, 1e-38))
    u = jax.random.uniform(key, g.shape, dtype=jnp.float32)
    keep = u < p
    safe_p = jnp.maximum(p, 1e-38)
    out = jnp.where(keep, g / safe_p, 0.0)
    return out.reshape(grads.shape), keep.reshape(grads.shape)


def expected_sample_size(weights: jax.Array, c: float | jax.Array = 1.0) -> jax.Array:
    """E|X| = sum_v c*w_v/W = c (whenever W > 0)."""
    return jnp.sum(inclusion_probs(weights, c))
