"""Subset-sampling-based competitors for Poisson pi-ps sampling (paper Sec 4).

The reduction (paper Sec 2.3): compute ``p_w(v) = c*w(v)/W_S`` for every
element and hand the resulting *subset sampling* (SS) instance to an SS
index.  Queries then cost whatever the SS index costs -- but any PPS update
(insert/delete/change_w) changes *every* ``p_w(v)``, so the SS structure
must be rebuilt in O(n).  That O(n)-vs-O(1) update gap is exactly what the
paper's Figures 2 and 4 measure, and what DIPS eliminates.

Implemented competitors:

  * ``BruteForcePPS``  -- dynamic array, O(n) query by scanning, O(1) update
    (the lowest-possible-update reference of Fig 2).
  * ``R_HSS``  [Tsai et al., COCOON'10]  -- dyadic probability groups,
    query visits *every* group index: O(log n + mu) query, rebuild on update.
  * ``R_BSS``  [Bringmann & Panagiotou, ICALP'12]  -- two-level dyadic
    grouping: only *hit* groups are visited, O(1 + mu) expected query
    (static; rebuild on update).
  * ``R_ODSS`` [Yi, Wang & Wei, SIGKDD'23]  -- same two-level structure
    with O(1) dynamic SS updates; under the PPS reduction an update still
    forces a full rebuild because all probabilities shift (paper Sec 2.5).

The two-level structure here is a faithful simplification of ODSS: depth-2
reduction ends in a direct scan over O(log log n) group-groups rather than
a lookup table (see DESIGN.md, "Baseline fidelity").
"""

from __future__ import annotations

import math
from typing import Dict, Hashable, Iterable, List, Optional, Tuple

import numpy as np

from .pps import Key, any_success_probability, truncated_geometric
from .samplers import DynamicWeightedArray, jump_scan


def _group_of(p: float, tail: int) -> int:
    """Dyadic group id: p in (2^-(i+1), 2^-i] -> i, capped at the tail group."""
    if p >= 1.0:
        return 0
    i = int(-math.log2(p))
    while p <= 2.0 ** -(i + 1):
        i += 1
    while p > 2.0**-i:
        i -= 1
    return min(i, tail)


class _GroupedSS:
    """Dyadic-group subset sampler over (key, prob) with O(1) updates.

    ``query_hit_groups`` enumerates groups via the ``level2`` index (exact
    SS over the groups' any-hit probabilities q_i), then samples members
    conditioned on the hit with a truncated-geometric scan.
    ``query_all_groups`` scans every group index (R-HSS behaviour).
    """

    def __init__(self, items: Iterable[Tuple[Key, float]], n_hint: int, two_level: bool) -> None:
        n = max(n_hint, 2)
        self.tail = max(1, math.ceil(2 * math.log2(n)))
        self.two_level = two_level
        self.groups: Dict[int, DynamicWeightedArray] = {}
        # level-2: group id -> any-hit probability q_i (direct scan; the
        # instance has O(log n) elements, its own grouping would give
        # O(log log n) -- a constant-size scan either way).
        self.q: Dict[int, float] = {}
        for k, p in items:
            self.insert(k, p)

    def _pbar(self, i: int) -> float:
        return 2.0**-i

    def _refresh_q(self, i: int) -> None:
        g = self.groups.get(i)
        if g is None or len(g) == 0:
            self.q.pop(i, None)
            self.groups.pop(i, None)
        else:
            self.q[i] = any_success_probability(self._pbar(i), len(g))

    def insert(self, key: Key, p: float) -> None:
        i = _group_of(p, self.tail)
        g = self.groups.get(i)
        if g is None:
            g = self.groups[i] = DynamicWeightedArray()
        g.insert(key, p)
        self._refresh_q(i)

    def delete(self, key: Key, p: float) -> None:
        i = _group_of(p, self.tail)
        self.groups[i].delete(key)
        self._refresh_q(i)

    def change_p(self, key: Key, p_old: float, p_new: float) -> None:
        i, j = _group_of(p_old, self.tail), _group_of(p_new, self.tail)
        if i == j:
            self.groups[i].change_w(key, p_new)
        else:
            self.delete(key, p_old)
            self.insert(key, p_new)

    # -- queries ---------------------------------------------------------------
    def _scan_group(self, i: int, rng: np.random.Generator, out: List[Key]) -> None:
        g = self.groups.get(i)
        if not g or len(g) == 0:
            return
        pbar = self._pbar(i)

        def accept(key: Key, p: float, u: float) -> bool:
            return u * pbar < p

        jump_scan(g, pbar, accept, rng, out)

    def _scan_group_conditioned(self, i: int, rng: np.random.Generator, out: List[Key]) -> None:
        """Sample group's members conditioned on >= 1 candidate (hit known)."""
        g = self.groups[i]
        t = len(g)
        pbar = self._pbar(i)
        if pbar >= 1.0:
            for k, p in g.items():
                if rng.random() * pbar < p:
                    out.append(k)
            return
        qi = self.q[i]
        log1m = math.log1p(-pbar)
        j = min(int(math.log1p(-qi * rng.random()) // log1m), t - 1)
        keys, probs = g.keys, g.weights
        while j < t:
            if rng.random() * pbar < probs[j]:
                out.append(keys[j])
            j += 1 + int(math.log1p(-rng.random()) // log1m)

    def query_all_groups(self, rng: np.random.Generator, out: List[Key]) -> None:
        """R-HSS: visit every dyadic index 0..tail -- O(log n + mu)."""
        for i in range(self.tail + 1):
            self._scan_group(i, rng, out)

    def query_hit_groups(self, rng: np.random.Generator, out: List[Key]) -> None:
        """R-BSS / R-ODSS: Bernoulli over q_i, then conditioned member scans."""
        for i, qi in self.q.items():
            if rng.random() < qi:
                self._scan_group_conditioned(i, rng, out)


class _SSReductionBase:
    """PPS facade over an SS index: updates recompute all probs (O(n))."""

    #: subclasses set this; benchmarks read it to label update complexity
    UPDATE_REBUILDS = True

    def __init__(self, items: Optional[Dict[Key, float]] = None, c: float = 1.0,
                 seed: Optional[int] = None, two_level: bool = True) -> None:
        self.c = c
        self.two_level = two_level
        self._rng = np.random.default_rng(seed)
        self._weights: Dict[Key, float] = {k: float(w) for k, w in (items or {}).items()}
        self._rebuild()

    def _rebuild(self) -> None:
        W = sum(self._weights.values())
        n = len(self._weights)
        pairs = []
        if W > 0:
            pairs = [(k, self.c * w / W) for k, w in self._weights.items() if w > 0]
        self._ss = _GroupedSS(pairs, n_hint=n, two_level=self.two_level)

    # PPS updates: every inclusion probability changes -> rebuild (Sec 2.3).
    def insert(self, key: Key, w: float) -> None:
        if key in self._weights:
            raise KeyError(f"duplicate key {key!r}")
        self._weights[key] = float(w)
        self._rebuild()

    def delete(self, key: Key) -> float:
        w = self._weights.pop(key)
        self._rebuild()
        return w

    def change_w(self, key: Key, w_new: float) -> None:
        self._weights[key] = float(w_new)
        self._rebuild()

    def query(self, rng: Optional[np.random.Generator] = None) -> List[Key]:
        rng = rng or self._rng
        out: List[Key] = []
        if self.two_level:
            self._ss.query_hit_groups(rng, out)
        else:
            self._ss.query_all_groups(rng, out)
        return out

    def __len__(self) -> int:
        return len(self._weights)

    @property
    def total_weight(self) -> float:
        return float(sum(self._weights.values()))

    def inclusion_probability(self, key: Key) -> float:
        W = self.total_weight
        return 0.0 if W <= 0 else self.c * self._weights[key] / W


class R_HSS(_SSReductionBase):
    """Reduction to HeterogeneousSS [27]: O(log n + mu) query."""

    def __init__(self, items=None, c: float = 1.0, seed: Optional[int] = None) -> None:
        super().__init__(items, c=c, seed=seed, two_level=False)


class R_BSS(_SSReductionBase):
    """Reduction to BringmannSS [5]: O(1 + mu) query, static."""

    def __init__(self, items=None, c: float = 1.0, seed: Optional[int] = None) -> None:
        super().__init__(items, c=c, seed=seed, two_level=True)


class R_ODSS(_SSReductionBase):
    """Reduction to ODSS [29]: optimal dynamic SS, but PPS updates still
    shift every probability, forcing the O(n) rebuild (paper Sec 2.5)."""

    def __init__(self, items=None, c: float = 1.0, seed: Optional[int] = None) -> None:
        super().__init__(items, c=c, seed=seed, two_level=True)


class BruteForcePPS:
    """Dynamic array + full scan: O(1) update, O(n) query (Fig 2 reference)."""

    UPDATE_REBUILDS = False

    def __init__(self, items: Optional[Dict[Key, float]] = None, c: float = 1.0,
                 seed: Optional[int] = None) -> None:
        self.c = c
        self._rng = np.random.default_rng(seed)
        self._arr = DynamicWeightedArray((k, float(w)) for k, w in (items or {}).items())

    def insert(self, key: Key, w: float) -> None:
        self._arr.insert(key, float(w))

    def delete(self, key: Key) -> float:
        return self._arr.delete(key)

    def change_w(self, key: Key, w_new: float) -> None:
        self._arr.change_w(key, float(w_new))

    def query(self, rng: Optional[np.random.Generator] = None) -> List[Key]:
        rng = rng or self._rng
        W = self._arr.total
        out: List[Key] = []
        if W <= 0:
            return out
        inv = self.c / W
        # vectorized scan: numpy uniforms beat a pure-python loop ~20x
        u = rng.random(len(self._arr))
        w = np.asarray(self._arr.weights)
        hits = np.nonzero(u < inv * w)[0]
        keys = self._arr.keys
        for i in hits:
            out.append(keys[i])
        return out

    def __len__(self) -> int:
        return len(self._arr)

    @property
    def total_weight(self) -> float:
        return self._arr.total

    def inclusion_probability(self, key: Key) -> float:
        W = self._arr.total
        return 0.0 if W <= 0 else self.c * self._arr.weight(key) / W


ALL_METHODS = {
    "DIPS": None,  # filled by core.__init__ to avoid a circular import
    "R-HSS": R_HSS,
    "R-BSS": R_BSS,
    "R-ODSS": R_ODSS,
    "BruteForce": BruteForcePPS,
}
