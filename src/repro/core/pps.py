"""Poisson pi-ps sampling (PPS) problem definitions.

Problem 1 (paper Sec 2.1): given a set S of n elements, a constant
``c in (0, 1]`` and a weight function ``w: S -> R_{>=0}``, draw a random
subset X of S such that every element v is included *independently* with
probability ``c * w(v) / W_S`` where ``W_S = sum_u w(u)``, and subsets are
independent across queries.

Dynamic operations: ``change_w(v, w)``, ``insert(v, w)``, ``delete(v)``.

This module holds the instance container, exact-probability helpers used by
the statistical tests, and the shared RNG conventions (truncated geometric
generation per the paper's Remark in Sec 2.1).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Hashable, Iterable, Tuple

import numpy as np

Key = Hashable


@dataclass
class PPSInstance:
    """A concrete <S, w, c> Poisson pi-ps problem instance."""

    weights: Dict[Key, float] = field(default_factory=dict)
    c: float = 1.0

    def __post_init__(self) -> None:
        if not (0.0 < self.c <= 1.0):
            raise ValueError(f"c must be in (0, 1], got {self.c}")
        for k, w in self.weights.items():
            if w < 0:
                raise ValueError(f"negative weight for {k!r}: {w}")

    @property
    def total_weight(self) -> float:
        return float(sum(self.weights.values()))

    def inclusion_probability(self, key: Key) -> float:
        """Exact P[key in X] = c * w(key) / W_S."""
        W = self.total_weight
        if W <= 0.0:
            return 0.0
        return self.c * self.weights[key] / W

    def inclusion_probabilities(self) -> Dict[Key, float]:
        W = self.total_weight
        if W <= 0.0:
            return {k: 0.0 for k in self.weights}
        return {k: self.c * w / W for k, w in self.weights.items()}

    def items(self) -> Iterable[Tuple[Key, float]]:
        return self.weights.items()

    def __len__(self) -> int:
        return len(self.weights)


class RandomStream:
    """Buffered uniform stream, drop-in for the Generator.random() calls on
    the query path.  One ``Generator.random(256)`` bulk draw costs ~1.5 us
    while 256 scalar draws cost ~80 us -- with ~32 draws per DIPS query the
    per-call dispatch overhead dominated the whole query (#Perf paper-side
    iteration P1).  ``.tolist()`` hands out native floats (no numpy-scalar
    boxing in math.log1p)."""

    __slots__ = ("_rng", "_buf", "_i", "_n")

    def __init__(self, rng: np.random.Generator, block: int = 256) -> None:
        self._rng = rng
        self._n = block
        self._buf = rng.random(block).tolist()
        self._i = 0

    def random(self, n=None):
        if n is not None:
            return self._rng.random(n)
        i = self._i
        if i >= self._n:
            self._buf = self._rng.random(self._n).tolist()
            i = 0
        self._i = i + 1
        return self._buf[i]

    def integers(self, *args, **kwargs):
        return self._rng.integers(*args, **kwargs)


def truncated_geometric(rng: np.random.Generator, p: float, q: float) -> int:
    """Sample G with Pr[G = i] = p * (1-p)^i / q  (paper Sec 2.1 Remark).

    Support is ``[0, N)`` with ``(1 - (1-p)^N) = q``; generated in O(1) as
    ``floor(log(1 - q*U) / log(1-p))``.
    """
    if p >= 1.0:
        return 0
    u = rng.random()
    return int(math.log1p(-q * u) // math.log1p(-p))


def geometric_jump(rng: np.random.Generator, p: float) -> int:
    """Gap to the next success of an iid Bernoulli(p) process (>= 1)."""
    if p >= 1.0:
        return 1
    u = rng.random()
    return 1 + int(math.log1p(-u) // math.log1p(-p))


def any_success_probability(p: float, t: int) -> float:
    """Exact 1 - (1-p)^t, computed stably.

    Used as the gate ``q`` of the candidate scan.  Algorithm 3 in the paper
    states ``q = W_T / W_S``; that choice is only a valid gate when it upper
    bounds the first-success mass ``1-(1-pbar)^t`` (true in every call site
    of the composed structure, where T spans the whole local instance).  We
    use the exact mass, which is correct for *any* subset T and changes
    neither the expected cost nor the distribution.  See DESIGN.md.
    """
    if p <= 0.0 or t <= 0:
        return 0.0
    if p >= 1.0:
        return 1.0
    return -math.expm1(t * math.log1p(-p))


def empirical_inclusion(counts: Dict[Key, int], repeats: int) -> Dict[Key, float]:
    return {k: v / repeats for k, v in counts.items()}


def max_abs_error(instance: PPSInstance, counts: Dict[Key, int], repeats: int) -> float:
    """Paper Sec 4.2 metric: max_e |phat(e) - p(e)| over all elements."""
    truth = instance.inclusion_probabilities()
    err = 0.0
    for k, p in truth.items():
        phat = counts.get(k, 0) / repeats
        err = max(err, abs(phat - p))
    return err
