"""Table lookup for O(log log n)-size PPS instances (paper Lemma 3.4).

After two rounds of size reduction the instance ``Phi^o = <S^o, w^o>`` has
``m = O(log_b log_b n)`` elements whose weights lie in ``(1, b^{dm}]``.  The
paper rounds every weight up to ``wbar(v) = ceil(w(v))``, encodes the rounded
weight vector as a radix-r number ``lambda`` (r > max possible wbar), and for
each ``lambda`` materializes an array ``A_lambda`` of ``(Wbar - m)^m``
entries so that a uniformly random entry is a subset T drawn with

    pbar(T) = prod_{v in T} wbar(v)/(Wbar-m)
            * prod_{u notin T} (Wbar-m-wbar(u))/(Wbar-m).

Rejection sampling (accept v in T iff U < c*w(v)/wbar(v) * (Wbar-m)/W)
corrects the overestimation, so each element lands in the output with
probability exactly ``c*w(v)/W`` -- despite the weight correlation that
makes naive rounding biased (paper Example 3.5).

Key observation (also how we validate the table): ``pbar`` *factorizes*, so
drawing T is equivalent to m independent Bernoulli(wbar(v)/(Wbar-m)) draws.
The materialized table is the O(1)-time theoretical device; the factorized
backend is its distribution-identical O(m)-time twin used when a table would
exceed the memory budget.  Both are exposed and cross-validated in tests.

``change_w`` updates ``lambda`` with the generalized bit operation of the
paper (Algorithm 2 line 16): lambda <- floor(lambda/r^v)*r^v
+ ceil(w)*r^{v-1} + lambda mod r^{v-1}.  Tables are built lazily per lambda
and memoized, so repeated weight states reuse their array.
"""

from __future__ import annotations

import math
from typing import Dict, Hashable, Iterable, List, Optional, Tuple

import numpy as np

from .pps import Key
from .samplers import DynamicWeightedArray


class RoundedLookup:
    """Lemma 3.4 structure over a fixed small element set.

    Parameters
    ----------
    items: (key, weight) pairs; weights must be > 1 (guaranteed by the
        normalization of Lemma 3.3: chunk-local weights lie in (1, b*n^2]).
    radix: the paper's ``r = b^{dm}``; any integer strictly greater than
        every possible rounded weight is equivalent.
    max_table_entries: memory budget; a lambda whose array would exceed it
        is served by the factorized backend instead.
    """

    def __init__(
        self,
        items: Iterable[Tuple[Key, float]],
        radix: int = 1 << 20,
        max_table_entries: int = 1 << 22,
        use_materialized: bool = True,
    ) -> None:
        items = list(items)
        self.slots: List[Key] = [k for k, _ in items]
        self.slot_of: Dict[Key, int] = {k: i for i, (k, _) in enumerate(items)}
        self.w: List[float] = [float(w) for _, w in items]
        self.radix = int(radix)
        self.max_table_entries = int(max_table_entries)
        self.use_materialized = use_materialized
        self._tables: Dict[int, Optional[np.ndarray]] = {}
        self._recompute()

    # -- bookkeeping ---------------------------------------------------------
    def _recompute(self) -> None:
        self.m = len(self.w)
        self.wbar = [int(math.ceil(wi)) for wi in self.w]
        self.W = float(sum(self.w))
        self.Wbar = int(sum(self.wbar))
        self.lam = 0
        for i in range(self.m - 1, -1, -1):  # lambda = (wbar(m)...wbar(1))_r
            self.lam = self.lam * self.radix + self.wbar[i]

    @property
    def total(self) -> float:
        return self.W

    def __len__(self) -> int:
        return self.m

    def is_valid(self) -> bool:
        """Lemma 3.4 preconditions: m >= 2, all w > 1, probs <= 1, r big enough."""
        if self.m < 2:
            return False
        denom = self.Wbar - self.m
        if denom <= 0:
            return False
        for wi, wb in zip(self.w, self.wbar):
            if not (wi > 1.0) or wb >= self.radix or wb > denom:
                return False
        return True

    # -- dynamic ops -----------------------------------------------------------
    def change_w(self, key: Key, w_new: float) -> None:
        """O(1): digit surgery on lambda (paper Algorithm 2, change_w)."""
        i = self.slot_of[key]
        new_digit = int(math.ceil(w_new))
        old_digit = self.wbar[i]
        self.W += w_new - self.w[i]
        self.Wbar += new_digit - old_digit
        r_i = self.radix**i  # r^{v-1} with 0-based slots
        self.lam = (
            (self.lam // (r_i * self.radix)) * (r_i * self.radix)
            + new_digit * r_i
            + self.lam % r_i
        )
        self.w[i] = float(w_new)
        self.wbar[i] = new_digit

    def insert(self, key: Key, w: float) -> None:
        # Beyond Lemma 3.4's interface (the composed index sizes the leaf
        # set statically); supported by re-encoding in O(m) = O(log log n).
        self.slot_of[key] = len(self.slots)
        self.slots.append(key)
        self.w.append(float(w))
        self._recompute()

    def delete(self, key: Key) -> float:
        i = self.slot_of.pop(key)
        w = self.w[i]
        last = len(self.slots) - 1
        if i != last:
            self.slots[i] = self.slots[last]
            self.w[i] = self.w[last]
            self.slot_of[self.slots[i]] = i
        self.slots.pop()
        self.w.pop()
        self._recompute()
        return w

    def items(self) -> Iterable[Tuple[Key, float]]:
        return zip(self.slots, self.w)

    # -- table construction ------------------------------------------------------
    def _build_table(self) -> Optional[np.ndarray]:
        """Materialize A_lambda: entry -> subset bitmask (paper Example 3.6)."""
        denom = self.Wbar - self.m
        size = denom**self.m
        if size <= 0 or size > self.max_table_entries or self.m > 16:
            return None
        table = np.empty(size, dtype=np.uint32)
        pos = 0
        for mask in range(1 << self.m):
            cnt = 1
            for i in range(self.m):
                cnt *= self.wbar[i] if (mask >> i) & 1 else denom - self.wbar[i]
            if cnt > 0:
                table[pos : pos + cnt] = mask
                pos += cnt
        assert pos == size, f"table fill mismatch: {pos} != {size}"
        return table

    def _table_for_lambda(self) -> Optional[np.ndarray]:
        if self.lam not in self._tables:
            self._tables[self.lam] = self._build_table()
        return self._tables[self.lam]

    # -- query ---------------------------------------------------------------
    def query_into(self, c: float, rng: np.random.Generator, out: List[Key]) -> None:
        if self.m == 0 or self.W <= 0.0:
            return
        denom = self.Wbar - self.m
        if not self.is_valid():
            # Degenerate leaf (single element / integer-boundary weights):
            # exact per-element Bernoulli, still O(m) = O(1) at the leaf.
            inv = c / self.W
            for i in range(self.m):
                if rng.random() < inv * self.w[i]:
                    out.append(self.slots[i])
            return
        table = self._table_for_lambda() if self.use_materialized else None
        if table is not None:
            mask = int(table[rng.integers(0, len(table))])
        else:
            # Factorized twin of the table: identical distribution.
            mask = 0
            for i in range(self.m):
                if rng.random() * denom < self.wbar[i]:
                    mask |= 1 << i
        # Rejection correcting the rounded-up probabilities.
        corr = c * denom / self.W
        i = 0
        while mask:
            if mask & 1:
                if rng.random() * self.wbar[i] < corr * self.w[i]:
                    out.append(self.slots[i])
            mask >>= 1
            i += 1

    # -- exact distribution (for tests) --------------------------------------
    def subset_distribution(self) -> Dict[int, float]:
        """Exact pbar over subsets (bitmask -> probability), from the table math."""
        denom = self.Wbar - self.m
        dist: Dict[int, float] = {}
        for mask in range(1 << self.m):
            p = 1.0
            for i in range(self.m):
                p *= (self.wbar[i] / denom) if (mask >> i) & 1 else (denom - self.wbar[i]) / denom
            dist[mask] = p
        return dist
