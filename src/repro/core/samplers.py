"""Building-block samplers (paper Sec 3.1, Appendix A).

``DynamicWeightedArray`` is the dynamic-array + hash-table structure used by
every building block (Algorithm 3): O(1) insert / delete (swap-with-last) /
change_w, with positions tracked in a hash map.

``jump_scan`` is the geometric candidate scan at the heart of Lemma 3.1
(bounded weight ratio) and Lemma 3.2 (subcritical weight): every array
position is a *candidate* independently with probability ``p_bar`` (an upper
bound of all true inclusion probabilities); candidates are visited via
truncated-geometric jumps in O(1) expected time per candidate, and each
candidate is kept with probability ``target/p_bar`` (rejection sampling).

Expected query cost:
  * Lemma 3.1 (weights in (wbar/b, wbar]):   E[candidates] <= b*c = O(1).
  * Lemma 3.2 (weights <= wbar = O(W_S/n^2)): E[candidates] = O(1/n).
"""

from __future__ import annotations

import math
from typing import Callable, Dict, Hashable, Iterable, List, Optional, Tuple

import numpy as np

from .pps import any_success_probability, Key


class DynamicWeightedArray:
    """Dynamic array of (key, weight) with O(1) ops (Algorithm 3 lines 1-18)."""

    __slots__ = ("keys", "weights", "pos", "total")

    def __init__(self, items: Iterable[Tuple[Key, float]] = ()) -> None:
        self.keys: List[Key] = []
        self.weights: List[float] = []
        self.pos: Dict[Key, int] = {}
        self.total: float = 0.0
        for k, w in items:
            self.insert(k, w)

    def __len__(self) -> int:
        return len(self.keys)

    def __contains__(self, key: Key) -> bool:
        return key in self.pos

    def weight(self, key: Key) -> float:
        return self.weights[self.pos[key]]

    def insert(self, key: Key, w: float) -> None:
        if key in self.pos:
            raise KeyError(f"duplicate key {key!r}")
        self.pos[key] = len(self.keys)
        self.keys.append(key)
        self.weights.append(w)
        self.total += w

    def change_w(self, key: Key, w: float) -> float:
        i = self.pos[key]
        old = self.weights[i]
        self.weights[i] = w
        self.total += w - old
        return old

    def delete(self, key: Key) -> float:
        i = self.pos.pop(key)
        w = self.weights[i]
        last_k = self.keys[-1]
        last_w = self.weights[-1]
        if last_k != key:
            self.keys[i] = last_k
            self.weights[i] = last_w
            self.pos[last_k] = i
        self.keys.pop()
        self.weights.pop()
        self.total -= w
        if not self.keys:
            self.total = 0.0  # snap float-drift to exact zero when empty
        return w

    def recompute_total(self) -> None:
        """Refresh the float accumulator (done on rebuilds to kill drift)."""
        self.total = float(sum(self.weights))

    def items(self) -> Iterable[Tuple[Key, float]]:
        return zip(self.keys, self.weights)


def jump_scan(
    arr: DynamicWeightedArray,
    p_bar: float,
    accept: Callable[[Key, float, float], bool],
    rng: np.random.Generator,
    out: List[Key],
) -> int:
    """Candidate scan of Algorithm 3 ``query()`` with an exact gate.

    Every position of ``arr`` is a candidate independently with probability
    ``p_bar``.  ``accept(key, weight, u)`` decides whether a candidate (with
    ``u ~ Uniform(0,1)``) enters ``out``; it must implement rejection with
    probability ``target_p / p_bar`` for correctness.

    Returns the number of candidates visited (for cost accounting).
    """
    t = len(arr)
    if t == 0 or p_bar <= 0.0:
        return 0
    keys = arr.keys
    weights = arr.weights
    visited = 0
    if p_bar >= 1.0:
        # Degenerate: every position is a candidate.
        for i in range(t):
            if accept(keys[i], weights[i], rng.random()):
                out.append(keys[i])
        return t
    q = any_success_probability(p_bar, t)
    if rng.random() > q:
        return 0
    log1m = math.log1p(-p_bar)
    # First candidate: truncated geometric with parameters (p_bar, q); the
    # exact gate guarantees support [0, t).
    j = int(math.log1p(-q * rng.random()) // log1m)
    if j >= t:  # float guard at the support boundary
        j = t - 1
    while j < t:
        visited += 1
        if accept(keys[j], weights[j], rng.random()):
            out.append(keys[j])
        j += 1 + int(math.log1p(-rng.random()) // log1m)
    return visited


class BoundedRatioSampler:
    """Lemma 3.1: weights of T within (wbar/b, wbar] for a constant b.

    The sampler answers sub-queries of the composed structure: each element
    v must enter the output with probability ``scale * w(v)`` where
    ``scale * wbar <= p_cap <= 1``.  For a bucket B used inside Lemma 3.3,
    ``scale = c * thin / w(B)`` (the bucket-local probability times the
    chunk thinning factor), and the candidate bound is
    ``p_bar = min(1, c * wbar / w(B))``.
    """

    __slots__ = ("arr", "wbar")

    def __init__(self, wbar: float, items: Iterable[Tuple[Key, float]] = ()) -> None:
        self.arr = DynamicWeightedArray(items)
        self.wbar = wbar

    # -- dynamic ops (all O(1)) -------------------------------------------
    def insert(self, key: Key, w: float) -> None:
        self.arr.insert(key, w)

    def delete(self, key: Key) -> float:
        return self.arr.delete(key)

    def change_w(self, key: Key, w: float) -> float:
        return self.arr.change_w(key, w)

    def __len__(self) -> int:
        return len(self.arr)

    @property
    def total(self) -> float:
        return self.arr.total

    # -- query -------------------------------------------------------------
    def query_into(
        self,
        c: float,
        thin: float,
        rng: np.random.Generator,
        out: List[Key],
    ) -> int:
        """Append a PPS sample to ``out``.

        Element v is included with probability ``thin * c * w(v) / total``
        (``thin`` folds the chunk-level thinning of Algorithm 1 line 26 into
        the bucket-level rejection, saving one uniform per element).
        """
        W = self.arr.total
        if W <= 0.0:
            return 0
        p_bar = c * self.wbar / W
        if p_bar > 1.0:
            p_bar = 1.0
        scale = thin * c / (W * p_bar)

        def accept(key: Key, w: float, u: float) -> bool:
            return u < scale * w

        return jump_scan(self.arr, p_bar, accept, rng, out)


def subcritical_scan_into(
    arr: DynamicWeightedArray,
    wbar: float,
    c: float,
    W_total: float,
    rng: np.random.Generator,
    out: List[Key],
) -> int:
    """Lemma 3.2 query over the *global* element array.

    Elements with weight > ``wbar`` (members of significant chunks, handled
    by the bucket/chunk path) are rejected outright; elements with weight
    <= wbar are kept with probability ``c*w/(W_total * p_bar)``.  Because
    ``wbar = O(W_total / n^2)``, the expected number of candidates is
    O(1/n): keeping one array over *all* elements (rather than a separate
    pool of non-significant elements) is what makes promotion/demotion of
    whole chunks free when the top chunk index r moves.  See DESIGN.md.
    """
    if W_total <= 0.0 or len(arr) == 0:
        return 0
    p_bar = c * wbar / W_total
    if p_bar > 1.0:
        p_bar = 1.0
    inv = c / (W_total * p_bar)

    def accept(key: Key, w: float, u: float) -> bool:
        if w > wbar:
            return False  # significant element: other path samples it
        return u < inv * w

    return jump_scan(arr, p_bar, accept, rng, out)


class DirectSampler:
    """Exact per-element Bernoulli sampler for O(1)-size leaf instances.

    After two rounds of size reduction the instance has O(log log n)
    elements, so scanning it is O(1); the materialized lookup table of
    Lemma 3.4 (``table_lookup.py``) trades this scan for a single table
    probe and is validated against this sampler.
    """

    __slots__ = ("arr",)

    def __init__(self, items: Iterable[Tuple[Key, float]] = ()) -> None:
        self.arr = DynamicWeightedArray(items)

    def insert(self, key: Key, w: float) -> None:
        self.arr.insert(key, w)

    def delete(self, key: Key) -> float:
        return self.arr.delete(key)

    def change_w(self, key: Key, w: float) -> float:
        return self.arr.change_w(key, w)

    def __len__(self) -> int:
        return len(self.arr)

    @property
    def total(self) -> float:
        return self.arr.total

    def query_into(self, c: float, rng: np.random.Generator, out: List[Key]) -> None:
        W = self.arr.total
        if W <= 0.0:
            return
        inv = c / W
        keys = self.arr.keys
        weights = self.arr.weights
        for i in range(len(keys)):
            if rng.random() < inv * weights[i]:
                out.append(keys[i])

    def items(self) -> Iterable[Tuple[Key, float]]:
        return self.arr.items()
