"""DIPS: the optimal dynamic index for Poisson pi-ps sampling (paper Sec 3).

Structure (Theorem 3.7) for an n-element instance <S, w, c>:

  * Every element lives in bucket ``B_j`` where ``b^j < w(v) <= b^{j+1}``.
  * Bucket ``B_j`` belongs to chunk ``C_t`` iff
    ``j in [t*L, (t+1)*L)`` with ``L = ceil(log_b n)`` (n frozen at build
    time; the structure rebuilds when the live size doubles or halves, so
    the bucket->chunk mapping changes only then -- amortized O(1), made
    worst-case O(1) by standard background rebuilding [Overmars 83]).
  * Chunk weights are normalized by ``b^{-t*L}`` so every bucket weight
    inside a chunk lies in ``(1, b*n^2]`` -- this bounds the *weight
    explosion* that blocks a direct port of subset-sampling indexes.
  * A query touches only the three *significant* chunks ``C_r, C_{r-1},
    C_{r-2}`` (r = highest non-empty chunk, located from W_S in O(1));
    every other element has weight <= W_S/(b*n^2) and is covered by the
    subcritical scan of Lemma 3.2 in O(1/n) expected time.
  * Each chunk's bucket-level instance is itself a PPS instance (weights
    normalized, c = 1) handled by a recursive node; after two reductions
    the instance size is O(log log n) and a leaf sampler finishes the job
    (exact per-element Bernoulli scan, or the Lemma 3.4 lookup table).

Every operation -- query, change_w, insert, delete -- is expected O(1);
space is O(n).
"""

from __future__ import annotations

import math
from typing import Dict, Hashable, Iterable, List, Optional, Tuple

import numpy as np

from .pps import Key, PPSInstance, RandomStream
from .samplers import (
    BoundedRatioSampler,
    DirectSampler,
    DynamicWeightedArray,
    subcritical_scan_into,
)
from .table_lookup import RoundedLookup

_DIRECT, _SR = 0, 1


class _Chunk:
    __slots__ = ("w", "child", "scale")

    def __init__(self, w: float, child: "PPSNode", scale: float) -> None:
        self.w = w
        self.child = child
        self.scale = scale


class PPSNode:
    """One level of the recursive structure (generic over element keys)."""

    __slots__ = (
        "b",
        "c",
        "threshold",
        "depth",
        "leaf_backend",
        "mode",
        "direct",
        "elems",
        "buckets",
        "chunks",
        "L",
        "old_size",
        "_logb",
    )

    def __init__(
        self,
        items: Iterable[Tuple[Key, float]],
        b: int = 4,
        c: float = 1.0,
        threshold: int = 16,
        depth: int = 0,
        leaf_backend: str = "direct",
    ) -> None:
        if b < 2:
            raise ValueError("b must be >= 2")
        self.b = b
        self.c = c
        self.threshold = max(2, threshold)
        self.depth = depth
        self.leaf_backend = leaf_backend
        self._logb = math.log(b)
        self._build(list(items))

    # -- construction -------------------------------------------------------
    def _build(self, items: List[Tuple[Key, float]]) -> None:
        n = len(items)
        self.old_size = n
        if n <= self.threshold:
            self.mode = _DIRECT
            self.direct = self._make_leaf(items)
            self.elems = None
            self.buckets = None
            self.chunks = None
            self.L = 1
            return
        self.mode = _SR
        self.direct = None
        self.elems = DynamicWeightedArray(items)
        self.buckets: Dict[int, BoundedRatioSampler] = {}
        self.chunks: Dict[int, _Chunk] = {}
        self.L = max(1, math.ceil(math.log(max(n, 2)) / self._logb))
        # Bulk: fill buckets, then create each chunk's child in one shot.
        for k, w in items:
            j = self._bucket_index(w)
            bkt = self.buckets.get(j)
            if bkt is None:
                bkt = BoundedRatioSampler(self._pow(j + 1))
                self.buckets[j] = bkt
            bkt.insert(k, w)
        per_chunk: Dict[int, List[int]] = {}
        for j in self.buckets:
            per_chunk.setdefault(self._chunk_of(j), []).append(j)
        for t, bucket_ids in per_chunk.items():
            scale = self._pow(-t * self.L)
            child_items = [(j, self.buckets[j].total * scale) for j in bucket_ids]
            child = PPSNode(
                child_items,
                b=self.b,
                c=1.0,
                threshold=self.threshold,
                depth=self.depth + 1,
                leaf_backend=self.leaf_backend,
            )
            w_chunk = float(sum(self.buckets[j].total for j in bucket_ids))
            self.chunks[t] = _Chunk(w_chunk, child, scale)

    def _make_leaf(self, items: List[Tuple[Key, float]]):
        if self.leaf_backend == "table" and len(items) >= 2:
            leaf = RoundedLookup(items)
            if leaf.is_valid():
                return leaf
        return DirectSampler(items)

    # -- arithmetic helpers ---------------------------------------------------
    def _pow(self, j: int) -> float:
        return float(self.b) ** j

    def _bucket_index(self, w: float) -> int:
        """j such that b^j < w <= b^{j+1} (floor-log with boundary repair)."""
        j = math.floor(math.log(w) / self._logb)
        # Repair float error at power-of-b boundaries.
        while w <= self._pow(j):
            j -= 1
        while w > self._pow(j + 1):
            j += 1
        return j

    def _chunk_of(self, j: int) -> int:
        return j // self.L  # floor division (negatives round toward -inf)

    # -- size bookkeeping -------------------------------------------------------
    def __len__(self) -> int:
        return len(self.direct) if self.mode == _DIRECT else len(self.elems)

    @property
    def total(self) -> float:
        return self.direct.total if self.mode == _DIRECT else self.elems.total

    def _items(self) -> List[Tuple[Key, float]]:
        src = self.direct.items() if self.mode == _DIRECT else self.elems.items()
        return list(src)

    def _maybe_rebuild(self) -> None:
        n = len(self)
        if self.mode == _DIRECT:
            if n > 2 * self.threshold:
                self._build(self._items())
        else:
            if n >= 2 * self.old_size or n <= self.old_size // 2:
                self._build(self._items())

    # -- dynamic operations (Algorithm 4) ---------------------------------------
    def insert(self, key: Key, w: float) -> None:
        if self.mode == _DIRECT:
            self.direct.insert(key, w)
        else:
            self.elems.insert(key, w)
            self._add_to_bucket(key, w)
        self._maybe_rebuild()

    def delete(self, key: Key) -> float:
        if self.mode == _DIRECT:
            w = self.direct.delete(key)
        else:
            w = self.elems.delete(key)
            self._remove_from_bucket(key, w)
        self._maybe_rebuild()
        return w

    def change_w(self, key: Key, w_new: float) -> None:
        if self.mode == _DIRECT:
            self.direct.change_w(key, w_new)
            return
        w_old = self.elems.change_w(key, w_new)
        j_old = self._bucket_index(w_old)
        j_new = self._bucket_index(w_new)
        if j_old == j_new:
            bkt = self.buckets[j_old]
            bkt.change_w(key, w_new)
            ch = self.chunks[self._chunk_of(j_old)]
            ch.w += w_new - w_old
            ch.child.change_w(j_old, bkt.total * ch.scale)
        else:
            self._remove_from_bucket(key, w_old, from_bucket=j_old)
            self._add_to_bucket(key, w_new)

    def _add_to_bucket(self, key: Key, w: float) -> None:
        j = self._bucket_index(w)
        bkt = self.buckets.get(j)
        is_new_bucket = bkt is None
        if is_new_bucket:
            bkt = BoundedRatioSampler(self._pow(j + 1))
            self.buckets[j] = bkt
        bkt.insert(key, w)
        t = self._chunk_of(j)
        ch = self.chunks.get(t)
        if ch is None:
            scale = self._pow(-t * self.L)
            child = PPSNode(
                [(j, bkt.total * scale)],
                b=self.b,
                c=1.0,
                threshold=self.threshold,
                depth=self.depth + 1,
                leaf_backend=self.leaf_backend,
            )
            self.chunks[t] = _Chunk(w, child, scale)
            return
        ch.w += w
        if is_new_bucket:
            ch.child.insert(j, bkt.total * ch.scale)
        else:
            ch.child.change_w(j, bkt.total * ch.scale)

    def _remove_from_bucket(self, key: Key, w: float, from_bucket: Optional[int] = None) -> None:
        j = self._bucket_index(w) if from_bucket is None else from_bucket
        bkt = self.buckets[j]
        bkt.delete(key)
        t = self._chunk_of(j)
        ch = self.chunks[t]
        ch.w -= w
        if len(bkt) == 0:
            del self.buckets[j]
            ch.child.delete(j)
            if len(ch.child) == 0:
                del self.chunks[t]
        else:
            ch.child.change_w(j, bkt.total * ch.scale)

    # -- query (Algorithm 1) --------------------------------------------------
    def query_into(self, rng: np.random.Generator, out: List[Key]) -> None:
        if self.mode == _DIRECT:
            self.direct.query_into(self.c, rng, out)
            return
        W = self.elems.total
        if W <= 0.0 or len(self.elems) == 0:
            return
        # Locate r = max non-empty chunk from W_S alone (Algorithm 1 line 18):
        # b^{rL} < W <= b^{(r+2)L}, so r in {x-2, x-1, x} with
        # x = floor(log_b(W)/L).  The +1 probe guards float drift of W.
        x = math.floor(math.log(W) / self._logb / self.L)
        r = None
        for cand in (x + 1, x, x - 1, x - 2):
            if cand in self.chunks:
                r = cand
                break
        if r is None:  # total-weight drift beyond the probe window: resync
            self.elems.recompute_total()
            W = self.elems.total
            if W <= 0.0:
                return
            x = math.floor(math.log(W) / self._logb / self.L)
            for cand in (x + 1, x, x - 1, x - 2):
                if cand in self.chunks:
                    r = cand
                    break
            if r is None:
                return
        ybuf: List[int] = []
        for i in (r, r - 1, r - 2):
            ch = self.chunks.get(i)
            if ch is None:
                continue
            thin = ch.w / W
            if thin > 1.0:
                thin = 1.0
            ybuf.clear()
            ch.child.query_into(rng, ybuf)
            for j in ybuf:
                self.buckets[j].query_into(self.c, thin, rng, out)
        # Lemma 3.2 over the whole array; significant elements (w > wbar_sub)
        # are rejected inside the scan.
        wbar_sub = self._pow((r - 2) * self.L)
        subcritical_scan_into(self.elems, wbar_sub, self.c, W, rng, out)

    # -- validation helpers (exercised by tests) ---------------------------------
    def check_invariants(self) -> None:
        if self.mode == _DIRECT:
            return
        n_in_buckets = 0
        for j, bkt in self.buckets.items():
            assert len(bkt) > 0, f"empty bucket {j} retained"
            lo, hi = self._pow(j), self._pow(j + 1)
            for k, w in bkt.arr.items():
                assert lo < w <= hi, f"element {k!r} w={w} outside bucket {j}"
            n_in_buckets += len(bkt)
        assert n_in_buckets == len(self.elems)
        for t, ch in self.chunks.items():
            child_ids = set(dict(ch.child._items()))
            expect = {j for j in self.buckets if self._chunk_of(j) == t}
            assert child_ids == expect, f"chunk {t}: {child_ids} != {expect}"
            w_sum = sum(self.buckets[j].total for j in expect)
            assert math.isclose(ch.w, w_sum, rel_tol=1e-6, abs_tol=1e-6)
            for j, w_norm in ch.child._items():
                assert math.isclose(
                    w_norm, self.buckets[j].total * ch.scale, rel_tol=1e-6, abs_tol=1e-6
                )
                assert w_norm > 1.0 - 1e-9, f"normalized weight {w_norm} <= 1"
            ch.child.check_invariants()


class DIPS:
    """Public dynamic index: O(1) expected query/update, O(n) space.

    >>> idx = DIPS({"a": 1.0, "b": 3.0}, c=1.0, seed=0)
    >>> sample = idx.query()          # P[a] = 0.25, P[b] = 0.75
    >>> idx.insert("c", 12.0)         # O(1) even though all probs changed
    >>> idx.change_w("a", 4.0)
    >>> _ = idx.delete("b")
    """

    def __init__(
        self,
        items: Optional[Dict[Key, float]] = None,
        c: float = 1.0,
        b: int = 4,
        leaf_threshold: int = 16,
        leaf_backend: str = "direct",
        seed: Optional[int] = None,
    ) -> None:
        if not (0.0 < c <= 1.0):
            raise ValueError(f"c must be in (0, 1], got {c}")
        self.c = c
        self.b = b
        self._rng = np.random.default_rng(seed)
        self._stream = RandomStream(self._rng)
        self._weights: Dict[Key, float] = {}
        self._zeros: set = set()
        self._peak_weight: float = 1.0  # drift-tolerance scale for checks
        positive: List[Tuple[Key, float]] = []
        for k, w in (items or {}).items():
            self._check_weight(w)
            self._weights[k] = float(w)
            if w > 0.0:
                positive.append((k, float(w)))
            else:
                self._zeros.add(k)
        self._node = PPSNode(
            positive, b=b, c=c, threshold=leaf_threshold, leaf_backend=leaf_backend
        )

    def _check_weight(self, w: float) -> None:
        if not (w >= 0.0) or math.isinf(w):
            raise ValueError(f"weights must be finite and >= 0, got {w}")
        if w > self._peak_weight:
            self._peak_weight = float(w)

    # -- dynamic operations --------------------------------------------------
    def insert(self, key: Key, w: float) -> None:
        if key in self._weights:
            raise KeyError(f"duplicate key {key!r}")
        self._check_weight(w)
        self._weights[key] = float(w)
        if w > 0.0:
            self._node.insert(key, float(w))
        else:
            self._zeros.add(key)

    def delete(self, key: Key) -> float:
        w = self._weights.pop(key)
        if key in self._zeros:
            self._zeros.discard(key)
        else:
            self._node.delete(key)
        return w

    def change_w(self, key: Key, w_new: float) -> None:
        self._check_weight(w_new)
        w_old = self._weights[key]
        self._weights[key] = float(w_new)
        if w_old > 0.0 and w_new > 0.0:
            self._node.change_w(key, float(w_new))
        elif w_old > 0.0:  # -> zero
            self._node.delete(key)
            self._zeros.add(key)
        elif w_new > 0.0:  # zero ->
            self._zeros.discard(key)
            self._node.insert(key, float(w_new))

    # -- queries ------------------------------------------------------------
    def query(self, rng: Optional[np.random.Generator] = None) -> List[Key]:
        out: List[Key] = []
        self._node.query_into(rng if rng is not None else self._stream, out)
        return out

    sample = query

    # -- introspection --------------------------------------------------------
    def __len__(self) -> int:
        return len(self._weights)

    def __contains__(self, key: Key) -> bool:
        return key in self._weights

    def weight(self, key: Key) -> float:
        return self._weights[key]

    @property
    def total_weight(self) -> float:
        return self._node.total

    def inclusion_probability(self, key: Key) -> float:
        W = self._node.total
        if W <= 0.0:
            return 0.0
        return self.c * self._weights[key] / W

    def to_instance(self) -> PPSInstance:
        return PPSInstance(dict(self._weights), c=self.c)

    def check_invariants(self) -> None:
        assert len(self._weights) == len(self._node) + len(self._zeros)
        live = sum(w for w in self._weights.values() if w > 0.0)
        # abs tolerance scales with the peak magnitude the accumulator saw
        tol = max(1e-9, 1e-10 * self._peak_weight)
        assert math.isclose(self._node.total, live, rel_tol=1e-6, abs_tol=tol)
        self._node.check_invariants()
