"""repro.core: DIPS -- optimal dynamic index for Poisson pi-ps sampling.

Host-side (paper-faithful, O(1) query / O(1) update / O(n) space):
  DIPS, PPSNode, and the building blocks of Sec 3.1 / 3.3.
Device-side (JAX, batched):
  pps_bernoulli_mask / pps_sample_indices (flat), BucketedIndex (TPU-adapted
  hierarchy), pps_gradient_mask (compression operator).
Competitors of Sec 4: R_HSS, R_BSS, R_ODSS, BruteForcePPS.
"""

from .pps import PPSInstance, max_abs_error, truncated_geometric
from .samplers import (
    BoundedRatioSampler,
    DirectSampler,
    DynamicWeightedArray,
    jump_scan,
    subcritical_scan_into,
)
from .table_lookup import RoundedLookup
from .dips import DIPS, PPSNode
from .baselines import ALL_METHODS, BruteForcePPS, R_BSS, R_HSS, R_ODSS
from .jax_sampler import (
    expected_sample_size,
    inclusion_probs,
    mask_to_indices,
    pps_bernoulli_mask,
    pps_gradient_mask,
    pps_sample_indices,
)
from .jax_index import (
    BucketedIndex,
    bucket_ids,
    bucketed_change_w,
    bucketed_change_w_at,
    bucketed_change_w_batch,
    bucketed_sample,
    build_bucketed_index,
    marginal_probs,
)

ALL_METHODS["DIPS"] = DIPS

__all__ = [
    "DIPS",
    "PPSNode",
    "PPSInstance",
    "BoundedRatioSampler",
    "DirectSampler",
    "DynamicWeightedArray",
    "RoundedLookup",
    "R_HSS",
    "R_BSS",
    "R_ODSS",
    "BruteForcePPS",
    "ALL_METHODS",
    "max_abs_error",
    "truncated_geometric",
    "jump_scan",
    "subcritical_scan_into",
    "mask_to_indices",
    "pps_bernoulli_mask",
    "pps_sample_indices",
    "pps_gradient_mask",
    "inclusion_probs",
    "expected_sample_size",
    "BucketedIndex",
    "build_bucketed_index",
    "bucketed_sample",
    "bucket_ids",
    "bucketed_change_w",
    "bucketed_change_w_at",
    "bucketed_change_w_batch",
    "marginal_probs",
]
