"""internvl2-26b [vlm]: InternLM2-20B language backbone -- 48L, d=6144,
48H GQA kv=8, d_ff=16384, vocab=92553 -- with the InternViT frontend
STUBBED to precomputed patch embeddings (n_patches=256).
[arXiv:2404.16821; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=92553, n_patches=256,
)

def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=512, n_patches=8)
