"""hymba-1.5b [hybrid]: 32L, d=1600, 25H GQA kv=5 (head_dim 64), d_ff=5504,
vocab=32001, parallel attention + mamba heads with ssm_state=16.
[arXiv:2411.13676; hf]

Simplifications recorded in DESIGN.md: no meta tokens; mamba branch without
the depthwise-conv prelude; per-branch RMS norms then mean combine.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab_size=32001, ssm_state=16, tie_embeddings=True,
)

def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=512, ssm_state=4)
