"""gemma-2b [dense]: 18L, d=2048, 8H MQA (kv=1), d_ff=16384, GeGLU,
head_dim=256, vocab=256000, sqrt(d) embedding scaling.
[arXiv:2403.08295; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
    vocab_size=256000, mlp_kind="geglu", head_dim=256,
    tie_embeddings=True, embed_scale=True,
)

def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
                          d_ff=128, vocab_size=512, head_dim=16)
