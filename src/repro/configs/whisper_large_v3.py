"""whisper-large-v3 [audio]: enc-dec, conv frontend stubbed to precomputed
frame embeddings.  32L decoder (and 32L encoder), d=1280, 20H MHA (kv=20),
d_ff=5120, vocab=51866.  [arXiv:2212.04356; unverified]

Whisper uses absolute sinusoidal positions (rope_theta=0) and GELU MLPs.
Note: the assigned train_4k/prefill_32k shapes exceed Whisper's native
448-token decoder context; we honor the assigned shapes (DESIGN.md).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-large-v3", family="encdec",
    n_layers=32, n_enc_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab_size=51866, mlp_kind="gelu", rope_theta=0.0,
    tie_embeddings=True, enc_seq=1500,
)

def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=4, d_ff=128, vocab_size=512, enc_seq=16)
