"""Model / run configuration system.

``ModelConfig`` captures everything needed to build any of the assigned
architectures; each ``configs/<arch>.py`` exports ``CONFIG`` with the exact
published numbers plus ``smoke()`` returning the reduced same-family config
used by CPU smoke tests.  ``repro.configs.registry`` maps --arch ids to
modules.  Input shapes (paper-assigned workload grid) live in ``SHAPES``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    mlp_kind: str = "swiglu"         # swiglu | geglu | gelu | none
    qk_norm: bool = False
    swa_window: int = 0              # 0 = full attention
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma-style sqrt(d) embedding scaling
    vocab_pad_to: int = 256
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # SSM / hybrid
    ssm_state: int = 16
    slstm_every: int = 0             # xlstm: 1 sLSTM per this many layers (0 = none)
    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 0                 # stub frontend sequence (audio frames)
    # vlm
    n_patches: int = 0               # stub vision tokens prepended
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "block"             # none | block (checkpoint each layer)
    scan_layers: bool = True
    attn_impl: str = "xla"           # xla | pallas (TPU runs)
    attn_chunk: int = 1024           # query-chunked attention (0 = dense)
    scan_unroll: int = 1             # unroll factor for the layer scan (cost probes)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0 and self.top_k > 0

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM/hybrid state or sliding-window KV."""
        return self.family in ("ssm", "hybrid") or self.swa_window > 0

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs autoregress (whisper via its decoder)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs; reason recorded in the dry-run table."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full quadratic attention; 500k decode infeasible (DESIGN.md)"
    return True, ""
