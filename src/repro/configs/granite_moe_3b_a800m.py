"""granite-moe-3b-a800m [moe]: 32L, d=1536, 24H GQA kv=8, per-expert
d_ff=512, vocab=49155, 40 experts top-8.  [hf:ibm-granite; hf]

Note: the bracketed hf pointer says "32 experts top-8" while the assignment
line says "MoE 40e top-8"; we follow the assignment line (40 experts) and
record the discrepancy in DESIGN.md.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab_size=49155, n_experts=40, top_k=8, tie_embeddings=True,
)

def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=32, vocab_size=512, n_experts=4, top_k=2)
