"""h2o-danube-3-4b [dense]: 24L, d=3840, 32H GQA kv=8, d_ff=10240,
vocab=32000, llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, d_ff=10240,
    vocab_size=32000, swa_window=4096,
)

def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=512, swa_window=8)
