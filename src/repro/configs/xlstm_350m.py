"""xlstm-350m [ssm]: 24L, d=1024, 4 heads, no FFN (d_ff=0), vocab=50304,
alternating sLSTM + mLSTM blocks (1 sLSTM per 6 layers).
[arXiv:2405.04517; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304, mlp_kind="none", slstm_every=6, tie_embeddings=True,
)

def smoke() -> ModelConfig:
    return CONFIG.replace(n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
                          vocab_size=512, slstm_every=2)
