"""Architecture registry: --arch <id> -> ModelConfig."""

from .base import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from . import (
    deepseek_7b,
    gemma_2b,
    granite_moe_3b_a800m,
    h2o_danube_3_4b,
    hymba_1_5b,
    internvl2_26b,
    mixtral_8x22b,
    qwen3_1_7b,
    whisper_large_v3,
    xlstm_350m,
)

_MODULES = {
    "whisper-large-v3": whisper_large_v3,
    "granite-moe-3b-a800m": granite_moe_3b_a800m,
    "mixtral-8x22b": mixtral_8x22b,
    "hymba-1.5b": hymba_1_5b,
    "xlstm-350m": xlstm_350m,
    "h2o-danube-3-4b": h2o_danube_3_4b,
    "deepseek-7b": deepseek_7b,
    "qwen3-1.7b": qwen3_1_7b,
    "gemma-2b": gemma_2b,
    "internvl2-26b": internvl2_26b,
}

REGISTRY = {k: m.CONFIG for k, m in _MODULES.items()}
ARCH_IDS = list(REGISTRY)


def get_config(arch_id: str) -> ModelConfig:
    return REGISTRY[arch_id]


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].smoke()
