"""Deterministic synthetic LM data.

Documents are generated from a counter-mode hash (stable across runs and
hosts), so any (step, seed) pair maps to the same batch on every worker --
which is what makes checkpoint-resume exactly reproducible and lets the
elastic tests compare runs across different device counts.

The token stream has learnable structure (a noisy order-2 Markov chain over
a banded transition table) so small models show decreasing loss within a
few hundred steps.
"""

from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


def _doc_rng(seed: int, doc_id: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, doc_id]))


def synth_document(seed: int, doc_id: int, length: int, vocab: int) -> np.ndarray:
    """Order-1 structured sequence over a small active alphabet.

    80% of transitions follow a fixed deterministic map on K = min(64,
    vocab) active tokens, so a small model sees every context often enough
    to drop the loss well below ln(vocab) within tens of steps.
    """
    rng = _doc_rng(seed, doc_id)
    K = min(64, vocab)
    toks = np.empty(length, np.int32)
    toks[0] = rng.integers(K)
    noise = rng.random(length)
    jumps = rng.integers(0, K, length)
    for i in range(1, length):
        if noise[i] < 0.8:
            toks[i] = (toks[i - 1] * 31 + 7) % K
        else:
            toks[i] = jumps[i]
    return toks


def batch_for_step(
    seed: int, step: int, batch: int, seq_len: int, vocab: int
) -> Dict[str, np.ndarray]:
    """Pure function (step -> batch): the basis of deterministic resume."""
    tokens = np.stack([
        synth_document(seed, step * batch + b, seq_len + 1, vocab)
        for b in range(batch)
    ])
    return {"tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32)}


def token_iterator(seed: int, batch: int, seq_len: int, vocab: int,
                   start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield batch_for_step(seed, step, batch, seq_len, vocab)
        step += 1
