"""Importance-sampling data pipeline driven by a dynamic PPS engine (the
paper's technique as a first-class training feature).

A pool of documents carries per-example weights (e.g. an EMA of recent
loss).  Every batch is assembled by repeated Poisson pi-ps queries against
a ``repro.engine`` sampler -- with the default "host-dips" backend each
query costs O(1) -- and after the step the trainer feeds per-example
losses back via ``update_weights``, each an O(1) ``change_w``.  This is
exactly the dynamic regime the paper targets: a single weight update
changes *every* inclusion probability, yet the index never rebuilds.  A
subset-sampling-based pipeline would pay O(pool) per weight update (see
benchmarks/bench_pipeline.py for the measured gap).  Device backends
("jax-bucketed", ...) swap in by name and serve ``sample_ids`` through
one batched device program per call.

Two estimator modes:
  * curriculum (default): plain loss-proportional sampling (biased toward
    hard examples, standard loss-based curriculum).
  * unbiased: records q_i = P[example i sampled] with every batch so the
    trainer can importance-correct the loss (w_i = 1/(pool * q_i)).
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from ..engine import make_engine
from . import synthetic


class DIPSSamplingPipeline:
    def __init__(
        self,
        pool_size: int,
        seq_len: int,
        vocab: int,
        seed: int = 0,
        c: float = 1.0,
        min_weight: float = 1e-3,
        max_weight: float = 1e3,
        ema: float = 0.9,
        doc_fn: Optional[Callable[[int, int, int, int], np.ndarray]] = None,
        engine: str = "host-dips",
    ) -> None:
        self.pool_size = pool_size
        self.seq_len = seq_len
        self.vocab = vocab
        self.seed = seed
        self.min_weight = min_weight
        self.max_weight = max_weight
        self.ema = ema
        self.engine_name = engine
        self._doc_fn = doc_fn or synthetic.synth_document
        # the engine's logical mirror IS the weight state -- no parallel
        # array to keep in sync (weights are clamped before change_w, so
        # reading back through the engine returns clamped values)
        self._index = make_engine(
            engine, {i: 1.0 for i in range(pool_size)}, c=c, seed=seed)
        self._rng = np.random.default_rng(seed + 1)
        self._lock = threading.Lock()
        self.query_count = 0

    # -- sampling ------------------------------------------------------------
    def sample_ids(self, batch: int) -> np.ndarray:
        """B distinct example ids via repeated PPS queries.

        Host engines answer one O(1) query at a time; device engines are
        asked for whole blocks of queries through ``query_batch`` so each
        block is a single fused program.  When the pool holds fewer than
        ``batch`` live documents the result is correspondingly shorter
        (never blocks waiting for ids that cannot exist).
        """
        out: List[int] = []
        seen = set()
        with self._lock:
            batch = min(batch, len(self._index))
            if self._index.NATIVE_BATCH:
                import jax

                while len(out) < batch:
                    key = jax.random.key(int(self._rng.integers(2**63 - 1)))
                    block = max(64, batch)
                    ids, cnts = self._index.query_batch(key, block)
                    self.query_count += block
                    for ks in self._index.decode_batch(ids, cnts):
                        for k in ks:
                            if k not in seen:
                                seen.add(k)
                                out.append(k)
                                if len(out) == batch:
                                    break
                        if len(out) == batch:
                            break
            else:
                while len(out) < batch:
                    self.query_count += 1
                    for k in self._index.query():
                        if k not in seen:
                            seen.add(k)
                            out.append(k)
                            if len(out) == batch:
                                break
        return np.asarray(out[:batch], np.int64)

    def batch(self, batch: int) -> Dict[str, np.ndarray]:
        ids = self.sample_ids(batch)
        toks = np.stack([
            self._doc_fn(self.seed, int(i), self.seq_len + 1, self.vocab)
            for i in ids
        ])
        with self._lock:
            # re-acquired after sample_ids: a concurrent remove_document
            # may have deleted a sampled id -- report probability 0 for it
            # rather than crash (update_weights likewise skips unknowns)
            W = self._index.total_weight
            probs = []
            for raw in ids:
                i = int(raw)
                probs.append(self._index.weight(i) if i in self._index else 0.0)
            q = np.asarray(probs) / max(W, 1e-30)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "example_ids": ids,
            "sample_probs": q,  # for the unbiased estimator mode
        }

    # -- feedback (the dynamic updates) ----------------------------------------
    def update_weights(self, ids: np.ndarray, losses: np.ndarray) -> None:
        """O(1) change_w per example -- the paper's dynamic operation.

        Ids removed from the pool since they were sampled are skipped.
        """
        with self._lock:
            for i, loss in zip(ids, losses):
                i = int(i)
                if i not in self._index:
                    continue
                w_old = self._index.weight(i)
                w_new = self.ema * w_old + (1 - self.ema) * float(loss)
                w_new = float(np.clip(w_new, self.min_weight, self.max_weight))
                self._index.change_w(i, w_new)

    def add_document(self, doc_id: int, weight: float = 1.0) -> None:
        with self._lock:
            self._index.insert(doc_id, weight)

    def remove_document(self, doc_id: int) -> None:
        with self._lock:
            self._index.delete(doc_id)

    # -- checkpointing ------------------------------------------------------------
    def state_dict(self) -> Dict[str, np.ndarray]:
        """Dense weights-by-doc-id array read back from the engine (removed
        documents hold 0 and are skipped on restore)."""
        with self._lock:
            items = {
                int(i): float(wv)
                for i, wv in self._index.snapshot().weights.items()
                if isinstance(i, (int, np.integer))
            }
            w = np.zeros(max(items, default=-1) + 1, np.float64)
            for i, wv in items.items():
                w[i] = wv
            return {"weights": w}

    def load_state_dict(self, state: Dict[str, np.ndarray]) -> None:
        w = state["weights"]
        with self._lock:
            self._index = make_engine(
                self.engine_name,
                {i: float(max(w[i], self.min_weight)) for i in range(len(w))
                 if w[i] > 0.0},
                c=self._index.c, seed=self.seed)


class StaticPipeline:
    """Uniform step-indexed pipeline (deterministic resume baseline)."""

    def __init__(self, batch: int, seq_len: int, vocab: int, seed: int = 0) -> None:
        self.batch_size = batch
        self.seq_len = seq_len
        self.vocab = vocab
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        return synthetic.batch_for_step(
            self.seed, step, self.batch_size, self.seq_len, self.vocab)
