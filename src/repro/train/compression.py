"""PPS gradient compression (the paper's sampler as a distributed-training
optimization).

Before the gradient all-reduce crosses the slow inter-pod links, each leaf
is sparsified by Poisson pi-ps sampling over coordinate magnitudes:
coordinate v survives with p_v = min(1, k*|g_v|/sum|g|) and is rescaled by
1/p_v, giving an *unbiased* estimator with expected density k/n (see
``repro.core.jax_sampler.pps_gradient_mask``).  With error feedback the
rejected mass is carried to the next step, recovering convergence at high
compression.

Semantics note: under pjit the all-reduce is implicit, so this transform
models compression at the reduction boundary; the roofline accounting in
EXPERIMENTS.md #Perf charges the inter-pod collective term with the
compressed byte count (density * dense bytes).

The per-leaf sampler is resolved from ``repro.engine.gradient_sampler`` by
name ("pps" default, "topk" baseline), so alternative sparsifiers plug in
without touching this module.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..engine import gradient_sampler
from ..models.common import unwrap


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    density: float = 0.1          # expected kept fraction per leaf
    error_feedback: bool = True
    min_leaf_size: int = 4096     # small leaves (norms, biases) stay dense
    sampler: str = "pps"          # key into repro.engine.gradient_sampler


class EFState(NamedTuple):
    residual: Any  # same structure as grads


def init_ef_state(params: Any) -> EFState:
    return EFState(jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params))


def compress_grads(
    cfg: CompressionConfig,
    grads: Any,
    step: jax.Array,
    ef: Optional[EFState] = None,
) -> Tuple[Any, Optional[EFState], dict]:
    """Returns (compressed_grads, new_ef_state, metrics)."""
    base_key = jax.random.key(0)
    sample_fn = gradient_sampler(cfg.sampler)
    leaves = jax.tree.leaves(unwrap(grads))
    total = sum(l.size for l in leaves)
    kept_acc = jnp.zeros((), jnp.float32)
    idx = [0]

    def one(g, r):
        i = idx[0]
        idx[0] += 1
        gf = g.astype(jnp.float32) + (r if r is not None else 0.0)
        if g.size < cfg.min_leaf_size:
            return gf.astype(g.dtype), jnp.zeros_like(gf), jnp.asarray(
                g.size, jnp.float32)
        key = jax.random.fold_in(jax.random.fold_in(base_key, i), step)
        k = cfg.density * gf.size
        out, keep = sample_fn(key, gf, k)
        # residual = what this step's sampler dropped; with the default
        # "pps" sampler E[resid] = 0 (unbiased), while biased samplers
        # ("topk") rely on error feedback carrying resid to converge
        resid = gf - out
        return out.astype(g.dtype), resid, jnp.sum(keep).astype(jnp.float32)

    if ef is not None:
        triples = jax.tree.map(one, grads, ef.residual)
    else:
        triples = jax.tree.map(lambda g: one(g, None), grads)
    is3 = lambda x: isinstance(x, tuple) and len(x) == 3 and not hasattr(x, "_fields")
    out = jax.tree.map(lambda t: t[0], triples, is_leaf=is3)
    resid = jax.tree.map(lambda t: t[1], triples, is_leaf=is3)
    kept = sum(jax.tree.leaves(jax.tree.map(lambda t: t[2], triples, is_leaf=is3)))
    new_ef = EFState(resid) if (ef is not None and cfg.error_feedback) else ef
    metrics = {"compression_kept_frac": kept / max(total, 1)}
    return out, new_ef, metrics


def make_grad_transform(cfg: CompressionConfig) -> Callable[[Any], Any]:
    """Stateless (no-EF) transform pluggable into make_train_step."""

    def transform(grads):
        out, _, _ = compress_grads(cfg, grads, jnp.zeros((), jnp.int32), None)
        return out

    return transform
