"""Fault-tolerant training loop.

Responsibilities:
  * jit/pjit the train step against the provided mesh (or single host)
  * deterministic data (step-indexed) or DIPS importance sampling with
    O(1) per-example weight feedback
  * periodic async checkpoints + auto-resume from the latest one
    (crash-kill-restart leaves the run bit-identical to an uninterrupted
    one when the pipeline is step-indexed; see tests/test_fault_tolerance)
  * straggler monitoring with pluggable mitigation
  * optional PPS gradient compression (error feedback carried in-loop)
"""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from ..data.pipeline import DIPSSamplingPipeline, StaticPipeline
from ..models.model import Model
from ..sharding import batch_shardings, param_shardings
from ..sharding.context import activation_mesh
from .checkpoint import CheckpointManager
from .compression import CompressionConfig, compress_grads, init_ef_state
from .optimizer import OptimizerConfig, adamw_update
from .step import TrainState, init_train_state
from .straggler import StragglerMonitor


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    batch: int = 8
    seq_len: int = 128
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    use_dips_pipeline: bool = False
    dips_pool: int = 2048
    compression: Optional[CompressionConfig] = None
    crash_at_step: Optional[int] = None  # fault-injection for tests


class Trainer:
    def __init__(
        self,
        model: Model,
        opt_cfg: OptimizerConfig,
        tcfg: TrainerConfig,
        mesh=None,
    ) -> None:
        self.model = model
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.monitor = StragglerMonitor()
        self.metrics_log: list = []
        self.ckpt = (
            CheckpointManager(tcfg.ckpt_dir) if tcfg.ckpt_dir else None
        )
        if tcfg.use_dips_pipeline:
            self.pipeline = DIPSSamplingPipeline(
                tcfg.dips_pool, tcfg.seq_len, model.cfg.vocab_size, seed=tcfg.seed)
        else:
            self.pipeline = StaticPipeline(
                tcfg.batch, tcfg.seq_len, model.cfg.vocab_size, seed=tcfg.seed)
        self._build_step()

    # -- step construction ------------------------------------------------------
    def _build_step(self) -> None:
        model, opt_cfg = self.model, self.opt_cfg
        comp = self.tcfg.compression

        def loss_and_metrics(params, batch):
            return model.loss(params, batch)

        def train_step(state: TrainState, batch, ef):
            (loss, metrics), grads = jax.value_and_grad(
                loss_and_metrics, has_aux=True)(state.params, batch)
            cmetrics = {}
            if comp is not None:
                grads, ef, cmetrics = compress_grads(
                    comp, grads, state.opt.step, ef)
            params, opt, om = adamw_update(opt_cfg, state.params, grads, state.opt)
            metrics = dict(metrics)
            metrics.update(om)
            metrics.update(cmetrics)
            # per-example loss for the DIPS feedback (cheap proxy: batch loss)
            return TrainState(params, opt), ef, metrics

        if self.mesh is not None:
            self._step = jax.jit(train_step, donate_argnums=(0, 2))
        else:
            self._step = jax.jit(train_step, donate_argnums=(0, 2))

    def _per_example_loss(self, params, batch) -> np.ndarray:
        # lightweight per-example signal for the importance weights
        logits = self.model.forward(params, batch)
        import jax.numpy as jnp

        lab = batch["labels"]
        lf = logits[..., : self.model.cfg.vocab_size].astype(jnp.float32)
        nll = -jax.nn.log_softmax(lf, -1)
        tok = jnp.take_along_axis(nll, lab[..., None], -1)[..., 0]
        return np.asarray(tok.mean(-1))

    # -- main loop ------------------------------------------------------------------
    def run(self, resume: bool = True) -> Dict[str, Any]:
        tcfg = self.tcfg
        key = jax.random.key(tcfg.seed)
        state = init_train_state(self.model, key)
        ef = init_ef_state(state.params) if tcfg.compression else None
        start_step = 0
        if self.ckpt and resume and self.ckpt.latest_step() is not None:
            (state, ef_restored), meta = self.ckpt.restore((state, ef))
            ef = ef_restored
            start_step = meta["step"]
            if isinstance(self.pipeline, DIPSSamplingPipeline) and "pipeline" in meta:
                self.pipeline.load_state_dict(
                    {"weights": np.asarray(meta["pipeline"], np.float64)})
            print(f"[trainer] resumed from step {start_step}")

        ctx = activation_mesh(self.mesh) if self.mesh is not None else None
        if ctx:
            ctx.__enter__()
        try:
            last_metrics: Dict[str, Any] = {}
            for step in range(start_step, tcfg.steps):
                if tcfg.crash_at_step is not None and step == tcfg.crash_at_step:
                    print(f"[trainer] injected crash at step {step}", flush=True)
                    import os

                    os._exit(42)  # simulated hard node failure
                t0 = time.time()
                if isinstance(self.pipeline, DIPSSamplingPipeline):
                    batch_np = self.pipeline.batch(tcfg.batch)
                else:
                    batch_np = self.pipeline.batch_at(step)
                batch = {
                    k: jax.numpy.asarray(v)
                    for k, v in batch_np.items()
                    if k in ("tokens", "labels", "patch_embeds", "frames")
                }
                state, ef, metrics = self._step(state, batch, ef)
                loss = float(metrics["loss"])
                if isinstance(self.pipeline, DIPSSamplingPipeline):
                    per_ex = self._per_example_loss(state.params, batch)
                    self.pipeline.update_weights(batch_np["example_ids"], per_ex)
                dur = time.time() - t0
                self.monitor.record(step, dur)
                row = {"step": step, "loss": loss, "sec": dur}
                self.metrics_log.append(row)
                last_metrics = {k: float(v) for k, v in metrics.items()}
                if step % tcfg.log_every == 0:
                    print(f"[trainer] step {step:5d} loss {loss:.4f} "
                          f"({dur*1e3:.0f} ms)", flush=True)
                next_step = step + 1
                if self.ckpt and next_step % tcfg.ckpt_every == 0:
                    extra = {}
                    if isinstance(self.pipeline, DIPSSamplingPipeline):
                        extra["pipeline"] = self.pipeline.state_dict()[
                            "weights"].tolist()
                    self.ckpt.save_async(next_step, (state, ef), extra_meta=extra)
            if self.ckpt:
                self.ckpt.wait()
                if self.ckpt.latest_step() != tcfg.steps:
                    extra = {}
                    if isinstance(self.pipeline, DIPSSamplingPipeline):
                        extra["pipeline"] = self.pipeline.state_dict()[
                            "weights"].tolist()
                    self.ckpt.save(tcfg.steps, (state, ef), extra_meta=extra)
            return {"state": state, "metrics": last_metrics,
                    "log": self.metrics_log,
                    "straggler_events": len(self.monitor.events)}
        finally:
            if ctx:
                ctx.__exit__(None, None, None)
            if self.ckpt:
                self.ckpt.wait()
