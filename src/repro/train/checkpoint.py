"""Mesh-agnostic, atomic, async checkpointing.

Layout:  <dir>/step_<n>/arrays.npz + meta.json, plus <dir>/LATEST.
Guarantees:
  * atomic -- written to step_<n>.tmp.<pid>, fsync'd, then os.rename;
    a crash mid-save can never corrupt the latest checkpoint (torn
    directories are ignored by ``latest_step`` and garbage-collected).
  * mesh-agnostic -- leaves are saved as *full* (unsharded) host arrays
    with the pytree structure; ``restore`` re-shards onto whatever mesh /
    device count the restoring job uses.  This is the elastic-scaling
    path: save on 256 chips, restore on 64 or 512.
  * async -- ``save_async`` snapshots to host memory synchronously (cheap)
    and writes in a background thread, overlapping I/O with the next
    training steps; ``wait`` joins before the next save or at exit.
"""

from __future__ import annotations

import itertools
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_TMP_PREFIX = ".tmp."


def _leaves(tree: Any) -> List[np.ndarray]:
    return [np.asarray(x) for x in jax.tree.leaves(tree)]


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3) -> None:
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._seq = itertools.count()

    # -- discovery -------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and _TMP_PREFIX not in p.name:
                try:
                    if (p / "meta.json").exists():
                        out.append(int(p.name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # -- save --------------------------------------------------------------------
    def _write(self, step: int, arrays: List[np.ndarray], meta: Dict) -> None:
        final = self.dir / f"step_{step}"
        tmp = self.dir / f"step_{step}{_TMP_PREFIX}{os.getpid()}.{next(self._seq)}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **{f"leaf_{i}": a for i, a in enumerate(arrays)})
        (tmp / "meta.json").write_text(json.dumps(meta))
        with open(tmp / "meta.json", "rb+") as f:
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        (self.dir / "LATEST").write_text(str(step))
        self._gc()

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)
        for p in self.dir.glob(f"*{_TMP_PREFIX}*"):
            shutil.rmtree(p, ignore_errors=True)

    def save(self, step: int, state: Any, extra_meta: Optional[Dict] = None) -> None:
        self.wait()  # serialize with any outstanding async write
        arrays = _leaves(state)  # host snapshot (gathers sharded arrays)
        meta = {"step": step, "time": time.time(), "n_leaves": len(arrays)}
        meta.update(extra_meta or {})
        self._write(step, arrays, meta)

    def save_async(self, step: int, state: Any, extra_meta: Optional[Dict] = None) -> None:
        self.wait()
        arrays = _leaves(state)  # snapshot NOW; write later
        meta = {"step": step, "time": time.time(), "n_leaves": len(arrays)}
        meta.update(extra_meta or {})

        def work():
            try:
                self._write(step, arrays, meta)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore ---------------------------------------------------------------------
    def restore(
        self,
        template: Any,
        step: Optional[int] = None,
        shardings: Optional[Any] = None,
    ) -> Tuple[Any, Dict]:
        """Restore into ``template``'s pytree structure.

        ``shardings``: optional matching tree (or prefix tree via Param
        nodes) of NamedSharding -- arrays are device_put with them, so the
        restoring mesh is free to differ from the saving mesh.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        d = self.dir / f"step_{step}"
        meta = json.loads((d / "meta.json").read_text())
        with np.load(d / "arrays.npz") as z:
            arrays = [z[f"leaf_{i}"] for i in range(meta["n_leaves"])]
        treedef = jax.tree.structure(template)
        flat_template = jax.tree.leaves(template)
        if len(flat_template) != len(arrays):
            raise ValueError(
                f"checkpoint has {len(arrays)} leaves, template needs "
                f"{len(flat_template)} (incompatible structure)")
        if shardings is not None:
            flat_sh = jax.tree.leaves(shardings)
            arrays = [jax.device_put(a, s) for a, s in zip(arrays, flat_sh)]
        else:
            arrays = [
                np.asarray(a).astype(t.dtype) if hasattr(t, "dtype") else a
                for a, t in zip(arrays, flat_template)
            ]
        return jax.tree.unflatten(treedef, arrays), meta
