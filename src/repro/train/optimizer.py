"""AdamW + schedules, operating directly on tagged (Param) trees.

The optimizer state mirrors the parameter tree (including the Param axis
tags), so the sharding rules that place parameters also place both Adam
moments -- a ZeRO-style fully sharded optimizer by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.common import unwrap


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def init_opt_state(params: Any) -> OptState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return OptState(m=zeros, v=jax.tree.map(jnp.zeros_like, params),
                    step=jnp.zeros((), jnp.int32))


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(unwrap(tree))
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(
    cfg: OptimizerConfig, params: Any, grads: Any, opt: OptState
) -> Tuple[Any, OptState, dict]:
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) if cfg.grad_clip > 0 else 1.0
    step = opt.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(
        lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32) * clip, opt.m, grads)
    new_v = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32) * clip),
        opt.v, grads)

    def upd(p, m, v):
        delta = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(new_m, new_v, step), metrics
