"""Train / serve step builders shared by the launcher, dry-run and tests.

``TrainState`` keeps everything (params + both Adam moments) as tagged
trees, so one call to ``sharding.param_shardings`` places the whole state
(ZeRO-sharded optimizer included).  Steps are pure and donate-friendly.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.model import Model
from .optimizer import OptimizerConfig, OptState, adamw_update, init_opt_state


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def init_train_state(model: Model, key: jax.Array) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=init_opt_state(params))


def make_train_step(
    model: Model,
    opt_cfg: OptimizerConfig,
    grad_transform: Optional[Callable[[Any], Any]] = None,
) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    """Returns train_step(state, batch) -> (state, metrics).

    ``grad_transform`` is the hook used by the PPS gradient-compression
    feature (applied to the gradient tree before the optimizer update).
    """

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        def loss_fn(p):
            return model.loss(p, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params)
        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, state.params, grads, state.opt)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return TrainState(new_params, new_opt), metrics

    return train_step


def make_eval_step(model: Model) -> Callable:
    def eval_step(params, batch):
        _, metrics = model.loss(params, batch)
        return metrics

    return eval_step
