"""Straggler detection and mitigation hooks.

On a real multi-pod fleet the per-host step time distribution develops a
slow tail (thermal throttling, failing HBM, noisy neighbours).  The monitor
keeps an EWMA/variance estimate of step durations and flags steps beyond
``threshold`` x EWMA.  Mitigations are pluggable callbacks; the built-in
one rebalances the data-shard assignment away from the slow host (advisory
on single-host CPU, exercised for real by the fleet launcher).

The detector is deliberately clock-agnostic (pass your own ``now``) so the
unit tests drive it with a fake clock.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


@dataclass
class StragglerEvent:
    step: int
    host: int
    duration: float
    ewma: float
    ratio: float


class StragglerMonitor:
    def __init__(
        self,
        threshold: float = 2.0,
        ewma_alpha: float = 0.1,
        warmup_steps: int = 5,
        on_straggler: Optional[Callable[[StragglerEvent], None]] = None,
    ) -> None:
        self.threshold = threshold
        self.alpha = ewma_alpha
        self.warmup = warmup_steps
        self.on_straggler = on_straggler
        self.ewma: Dict[int, float] = {}
        self.count: Dict[int, int] = {}
        self.events: List[StragglerEvent] = []

    def record(self, step: int, duration: float, host: int = 0) -> Optional[StragglerEvent]:
        n = self.count.get(host, 0)
        prev = self.ewma.get(host, duration)
        ewma = duration if n == 0 else (1 - self.alpha) * prev + self.alpha * duration
        self.count[host] = n + 1
        event = None
        if n >= self.warmup and prev > 0 and duration > self.threshold * prev:
            event = StragglerEvent(step, host, duration, prev, duration / prev)
            self.events.append(event)
            if self.on_straggler is not None:
                self.on_straggler(event)
            # do not fold outliers into the baseline
        else:
            self.ewma[host] = ewma
        return event


class ShardRebalancer:
    """Data-shard reassignment policy: slow hosts shed shards to fast ones.

    ``assignment[h]`` is the list of data-shard ids host h currently owns.
    ``rebalance`` moves one shard from the straggler to the least-loaded
    host; repeated events drain the slow host gradually (and a recovered
    host earns shards back through ``restore``).
    """

    def __init__(self, n_hosts: int, n_shards: int) -> None:
        self.assignment: Dict[int, List[int]] = {
            h: [s for s in range(n_shards) if s % n_hosts == h]
            for h in range(n_hosts)
        }

    def rebalance(self, slow_host: int) -> Optional[int]:
        if len(self.assignment.get(slow_host, [])) <= 1:
            return None  # never fully drain: the host still heartbeats
        target = min(self.assignment, key=lambda h: len(self.assignment[h]))
        if target == slow_host:
            return None
        shard = self.assignment[slow_host].pop()
        self.assignment[target].append(shard)
        return shard

    def restore(self, recovered_host: int) -> Optional[int]:
        donor = max(self.assignment, key=lambda h: len(self.assignment[h]))
        if donor == recovered_host or len(self.assignment[donor]) <= 1:
            return None
        shard = self.assignment[donor].pop()
        self.assignment[recovered_host].append(shard)
        return shard
