"""Sharding layer: logical-axis rules and mesh helpers."""

from .rules import (
    AXIS_MAP,
    DEFAULT_RULES,
    batch_shardings,
    data_axes,
    decode_state_shardings,
    param_shardings,
    replicated,
    spec_for_axes,
)

__all__ = [
    "AXIS_MAP",
    "DEFAULT_RULES",
    "batch_shardings",
    "data_axes",
    "decode_state_shardings",
    "param_shardings",
    "replicated",
    "spec_for_axes",
]
