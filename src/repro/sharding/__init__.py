"""Sharding layer: logical-axis rules and mesh helpers."""

from .context import SLOT_AXIS, activation_mesh, current_mesh, slot_mesh
from .rules import (
    AXIS_MAP,
    DEFAULT_RULES,
    batch_shardings,
    data_axes,
    decode_state_shardings,
    param_shardings,
    replicated,
    spec_for_axes,
)

__all__ = [
    "SLOT_AXIS",
    "activation_mesh",
    "current_mesh",
    "slot_mesh",
    "AXIS_MAP",
    "DEFAULT_RULES",
    "batch_shardings",
    "data_axes",
    "decode_state_shardings",
    "param_shardings",
    "replicated",
    "spec_for_axes",
]
