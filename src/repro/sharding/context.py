"""Mesh context for activation sharding constraints inside model code.

Models call ``constrain(x, spec_entries...)`` at a handful of well-chosen
points (scores einsum, embeddings, logits).  Outside a mesh context (CPU
unit tests, single-device runs) these are no-ops; the launcher and dry-run
enter ``with activation_mesh(mesh): ...`` so the same model code lowers
with fully sharded activations on the production meshes.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

#: axis name used by 1-D slot/pool meshes (engine/sharded.py)
SLOT_AXIS = "shard"


def slot_mesh(axis: str = SLOT_AXIS) -> Mesh:
    """1-D mesh over every device of the active mesh (or all local devices).

    The sharded sampler engine partitions *slots*, not activations, so it
    flattens whatever mesh the launcher entered into a single named axis;
    outside any mesh context it spans ``jax.devices()``.  On a one-device
    host this degenerates to a 1-device mesh -- the same program text
    runs unchanged, which is what the CPU agreement tests exercise.
    """
    mesh = current_mesh()
    devs = (
        mesh.devices.reshape(-1)
        if mesh is not None
        else np.asarray(jax.devices())
    )
    return Mesh(devs.reshape(-1), (axis,))


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def activation_mesh(mesh: Optional[Mesh]):
    prev = getattr(_STATE, "mesh", None)
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.mesh = prev


def dp_axes() -> Optional[Tuple[str, ...]]:
    mesh = current_mesh()
    if mesh is None:
        return None
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(name: str) -> int:
    mesh = current_mesh()
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def constrain(x: jax.Array, *entries) -> jax.Array:
    """with_sharding_constraint if a mesh is active; validates divisibility.

    Each entry is None, a mesh-axis name, or a tuple of mesh-axis names
    ("__dp__" expands to the data axes).  Entries whose product does not
    divide the corresponding dim are dropped (replicated) rather than
    erroring, so one call site serves every architecture.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = []
    for dim, e in zip(x.shape, entries):
        if e == "__dp__":
            e = dp_axes()
        if e is None:
            spec.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if size > 1 and dim % size == 0:
            spec.append(axes if len(axes) > 1 else axes[0])
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
