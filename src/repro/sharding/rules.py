"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Models tag every parameter dimension with a logical name; this module maps
those names onto the production mesh:

  mesh axes:  ("data", "model")           single pod (16 x 16)
              ("pod", "data", "model")    multi-pod  (2 x 16 x 16)

Strategy (defaults; per-cell overrides drive the #Perf hillclimbs):
  * tensor-parallel ("model"):  ffn, fused head dims (H*Dh, K*Dh), vocab.
    Fused head dims are always divisible by 16 even when head *counts*
    (25, 20, 24) are not -- the reshape to (H, Dh) is left to GSPMD.
  * fully-sharded params ("data"): the `embed` dimension -- FSDP *within*
    a pod; the "pod" axis is pure data parallelism (gradient all-reduce
    crosses the pod boundary, parameter all-gathers never do).
  * experts: expert-parallel over "data" when the expert count divides it,
    else replicated with their ffn dim model-sharded.

Assignment is greedy per-tensor: each dim tries its candidate mesh axes in
priority order; an axis is taken at most once per tensor and only when the
dim size is divisible by the axis size.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.common import Param, axes_of, is_param

#: logical axis -> ordered mesh-axis candidates (abstract names)
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "vocab": ("tensor",),
    "ffn": ("tensor",),
    "ffn_inner": ("tensor",),
    "expert_ffn": ("tensor",),   # but see the min-shard guard below
    "heads_x_dim": ("tensor",),
    "kv_x_dim": ("tensor",),
    "heads": ("tensor",),
    "embed": ("fsdp",),
    "embed_out": ("tensor",),
    "experts": ("expert",),
    # never sharded
    "layers": (), "layers_outer": (), "head_dim": (), "kv_heads": (),
}

#: abstract name -> concrete mesh axis
AXIS_MAP = {"tensor": "model", "fsdp": "data", "expert": "data"}


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes carrying the batch (pure DP): ("pod","data") or ("data",)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def spec_for_axes(
    mesh: Mesh,
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    rules: Optional[Dict[str, Tuple[str, ...]]] = None,
) -> P:
    rules = rules or DEFAULT_RULES
    used: set = set()
    out = []
    for name, dim in zip(axes, shape):
        assigned = None
        for cand in (rules.get(name, ()) if name else ()):
            mesh_ax = AXIS_MAP.get(cand, cand)
            if mesh_ax not in mesh.axis_names or mesh_ax in used:
                continue
            size = _axis_size(mesh, mesh_ax)
            if dim % size != 0:
                continue
            # tiny per-expert FFNs (granite: d_ff=512) are cheaper to
            # replicate than to TP-shard to 32-wide fragments whose
            # dispatch collectives dwarf the compute (#Perf iteration A2)
            if name == "expert_ffn" and dim // size < 128:
                continue
            assigned = mesh_ax
            used.add(mesh_ax)
            break
        out.append(assigned)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_shardings(mesh: Mesh, tagged_tree: Any, rules=None) -> Any:
    """NamedSharding tree for a tagged (Param-carrying) tree.

    Works on abstract trees (eval_shape output) -- no allocation.
    """

    def one(p):
        if is_param(p):
            spec = spec_for_axes(mesh, p.axes, p.value.shape, rules)
        else:
            spec = P()
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, tagged_tree, is_leaf=is_param)


def batch_shardings(mesh: Mesh, batch_specs: Any) -> Any:
    """Shard dim 0 (global batch) over the data axes when divisible."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([_axis_size(mesh, a) for a in dp]))

    def one(s):
        if s.shape and s.shape[0] % dp_size == 0 and dp_size > 1:
            return NamedSharding(mesh, P(dp, *([None] * (len(s.shape) - 1))))
        if s.shape and len(dp) > 1 and s.shape[0] % _axis_size(mesh, "data") == 0:
            return NamedSharding(mesh, P("data", *([None] * (len(s.shape) - 1))))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, batch_specs)


def decode_state_shardings(mesh: Mesh, state_abs: Any, batch: int) -> Any:
    """Heuristic sharding for decode caches/states.

    batch dim -> data axes; then the largest remaining dim divisible by
    "model" -> model (KV seq for full-attention caches, feature dims for
    SSM states).  Keeps every multi-GiB decode buffer fully distributed.
    """
    dp = data_axes(mesh)
    dp_size = int(np.prod([_axis_size(mesh, a) for a in dp]))
    model_size = _axis_size(mesh, "model")

    def one(s):
        shape = s.shape
        spec: list = [None] * len(shape)
        used_batch = False
        for i, d in enumerate(shape):
            if d == batch and not used_batch and i <= 2:
                if batch % dp_size == 0 and dp_size > 1:
                    spec[i] = dp
                    used_batch = True
                elif batch % _axis_size(mesh, "data") == 0 and _axis_size(mesh, "data") > 1:
                    spec[i] = "data"
                    used_batch = True
        # largest remaining dim divisible by model axis
        best, best_dim = -1, 0
        for i, d in enumerate(shape):
            if spec[i] is None and d % model_size == 0 and d > best_dim and d >= model_size:
                best, best_dim = i, d
        if best >= 0:
            spec[best] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, state_abs)


def replicated(mesh: Mesh, tree: Any) -> Any:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
