"""Pure-jnp oracle for the fused PPS Bernoulli-mask kernel.

Semantics shared with the kernel (bit-exact): element v of query b is
included iff ``bits[b, v] < threshold(v)`` where

    threshold(v) = u32(min(c * w_v / W, 1) * 2^32)

computed in float32 exactly as the kernel computes it.  ``bits`` are the
uniform uint32 random bits (supplied for validation; generated in-kernel by
``pltpu.prng_random_bits`` on the TPU path).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

TWO32 = 4294967296.0  # 2**32


def thresholds(weights: jax.Array, scale: jax.Array) -> jax.Array:
    """u32 comparison thresholds; `scale` is c / W (f32 scalar)."""
    p = jnp.minimum(weights.astype(jnp.float32) * scale, 1.0)
    # f32 * 2^32 then to uint32 via uint64 to avoid overflow UB.
    t = jnp.minimum(p * jnp.float32(TWO32), jnp.float32(TWO32 - 256.0))
    return t.astype(jnp.uint32)


def pps_mask_ref(weights: jax.Array, scale: jax.Array, bits: jax.Array) -> jax.Array:
    """(B, n) int8 inclusion mask -- the oracle the kernel must match exactly."""
    t = thresholds(weights, scale)
    return (bits < t[None, :]).astype(jnp.int8)
