"""Pallas TPU kernel: fused batched Poisson pi-ps Bernoulli sampling.

Motivation (roofline): the flat batched sampler is pure memory traffic.
The naive XLA lowering materializes (B, n) float32 uniforms in HBM
(4 bytes), re-reads them (4), and writes the mask (1) => ~9 bytes/cell.
This kernel generates random bits *inside* VMEM with the TPU hardware PRNG
and streams out only the int8 mask plus the (n,) weights => ~(1 + 4/B)
bytes/cell, an ~8x cut of the memory-roofline term (EXPERIMENTS.md #Perf).

Two entry points share the threshold/compare body:
  * ``pps_mask_kernel_fused``: pltpu.prng_seed / prng_random_bits per tile
    (TPU target; interpret mode stubs the PRNG to zeros, so statistical
    validation of this path runs on real hardware only).
  * ``pps_mask_kernel_bits``: random bits arrive as an input operand --
    bit-exact against ``ref.pps_mask_ref`` on CPU (interpret=True tests).

Tiling: grid (B/TB, n/TN); weights block (1, TN) is broadcast down the
batch-tile rows; mask block (TB, TN) int8.  TN defaults to 512 lanes
(4 * 128) and TB to 256 sublanes -- a (256, 512) int8 tile is 128KiB in
VMEM, comfortably under the ~16MiB/core budget with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import TWO32

DEFAULT_TB = 256
DEFAULT_TN = 512


def _threshold_tile(w_tile: jax.Array, scale: jax.Array) -> jax.Array:
    p = jnp.minimum(w_tile.astype(jnp.float32) * scale, 1.0)
    t = jnp.minimum(p * jnp.float32(TWO32), jnp.float32(TWO32 - 256.0))
    return t.astype(jnp.uint32)


def _mask_body(w_ref, scale_ref, bits, o_ref):
    t = _threshold_tile(w_ref[...], scale_ref[0])  # (1, TN)
    o_ref[...] = (bits < t).astype(jnp.int8)


def pps_mask_kernel_bits(w_ref, scale_ref, bits_ref, o_ref):
    """Validation path: bits supplied as an operand."""
    _mask_body(w_ref, scale_ref, bits_ref[...], o_ref)


def pps_mask_kernel_fused(w_ref, scale_ref, seed_ref, o_ref):
    """TPU path: per-tile hardware PRNG; seed derived from the grid point."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    pltpu.prng_seed(seed_ref[0] + i * nj + j)
    bits = pltpu.prng_random_bits(o_ref.shape)
    _mask_body(w_ref, scale_ref, bits, o_ref)


def _specs(tb: int, tn: int, fused: bool):
    w_spec = pl.BlockSpec((1, tn), lambda i, j: (0, j))
    scale_spec = pl.BlockSpec(memory_space=pltpu.SMEM)
    o_spec = pl.BlockSpec((tb, tn), lambda i, j: (i, j))
    if fused:
        seed_spec = pl.BlockSpec(memory_space=pltpu.SMEM)
        return [w_spec, scale_spec, seed_spec], o_spec
    bits_spec = pl.BlockSpec((tb, tn), lambda i, j: (i, j))
    return [w_spec, scale_spec, bits_spec], o_spec


@functools.partial(
    jax.jit, static_argnames=("tb", "tn", "interpret")
)
def pps_mask_bits_call(
    weights2d: jax.Array,   # (1, n_padded) f32
    scale: jax.Array,       # (1,) f32 in SMEM
    bits: jax.Array,        # (B_padded, n_padded) uint32
    *,
    tb: int = DEFAULT_TB,
    tn: int = DEFAULT_TN,
    interpret: bool = True,
) -> jax.Array:
    B, n = bits.shape
    grid = (B // tb, n // tn)
    in_specs, o_spec = _specs(tb, tn, fused=False)
    return pl.pallas_call(
        pps_mask_kernel_bits,
        grid=grid,
        in_specs=in_specs,
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((B, n), jnp.int8),
        interpret=interpret,
    )(weights2d, scale, bits)


@functools.partial(
    jax.jit, static_argnames=("batch", "tb", "tn", "interpret")
)
def pps_mask_fused_call(
    weights2d: jax.Array,   # (1, n_padded) f32
    scale: jax.Array,       # (1,) f32
    seed: jax.Array,        # (1,) uint32
    *,
    batch: int,
    tb: int = DEFAULT_TB,
    tn: int = DEFAULT_TN,
    interpret: bool = False,
) -> jax.Array:
    n = weights2d.shape[1]
    grid = (batch // tb, n // tn)
    in_specs, o_spec = _specs(tb, tn, fused=True)
    kwargs = {}
    if interpret:
        kwargs["interpret"] = pltpu.InterpretParams()
    return pl.pallas_call(
        pps_mask_kernel_fused,
        grid=grid,
        in_specs=in_specs,
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((batch, n), jnp.int8),
        **kwargs,
    )(weights2d, scale, seed)
