"""Jit'd public wrapper for the fused PPS sampling kernel.

``pps_sample_mask`` pads (batch, n) to tile multiples, dispatches to the
bit-input kernel (validation, CPU interpret) or the fused-PRNG kernel (TPU),
and slices the padding back off.  Weights with zero total yield an empty
mask.  The oracle lives in ``ref.py``; ``tests/test_kernels.py`` sweeps
shapes x dtypes x c and asserts bit-exact agreement.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .kernel import DEFAULT_TB, DEFAULT_TN, pps_mask_bits_call, pps_mask_fused_call


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit, static_argnames=("batch", "tb", "tn", "fused_rng", "interpret")
)
def pps_sample_mask(
    key: jax.Array,
    weights: jax.Array,
    c: float = 1.0,
    *,
    batch: int,
    tb: int = DEFAULT_TB,
    tn: int = DEFAULT_TN,
    fused_rng: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """(batch, n) int8 inclusion mask with P[mask=1] = min(c*w/W, 1).

    fused_rng=False: bits generated with jax.random outside the kernel
    (bit-exact vs ref; the validation configuration).
    fused_rng=True: TPU-resident PRNG -- the production configuration whose
    HBM traffic is mask-only.
    """
    n = weights.shape[0]
    w = _pad_to(weights.astype(jnp.float32), tn, 0)[None, :]  # (1, n_pad)
    total = jnp.sum(weights.astype(jnp.float32))
    scale = jnp.where(total > 0, c / jnp.maximum(total, 1e-38), 0.0)
    scale = jnp.asarray([scale], jnp.float32)
    b_pad = (-batch) % tb + batch
    if fused_rng:
        seed = jax.random.key_data(key).reshape(-1)[:1].astype(jnp.uint32)
        mask = pps_mask_fused_call(
            w, scale, seed, batch=b_pad, tb=tb, tn=tn, interpret=interpret
        )
    else:
        bits = jax.random.bits(key, (b_pad, w.shape[1]), jnp.uint32)
        mask = pps_mask_bits_call(w, scale, bits, tb=tb, tn=tn, interpret=interpret)
    return mask[:batch, :n]


def pps_sample_mask_ref(key: jax.Array, weights: jax.Array, c: float = 1.0, *, batch: int,
                        tb: int = DEFAULT_TB, tn: int = DEFAULT_TN) -> jax.Array:
    """Oracle with the identical padding + bit stream as the kernel path."""
    n = weights.shape[0]
    w = _pad_to(weights.astype(jnp.float32), tn, 0)
    total = jnp.sum(weights.astype(jnp.float32))
    scale = jnp.where(total > 0, c / jnp.maximum(total, 1e-38), 0.0).astype(jnp.float32)
    b_pad = (-batch) % tb + batch
    bits = jax.random.bits(key, (b_pad, w.shape[0]), jnp.uint32)
    mask = ref.pps_mask_ref(w, scale, bits)
    return mask[:batch, :n]
