"""Pallas TPU kernels for the framework's compute hot-spots.

pps_sample/       fused batched Poisson pi-ps Bernoulli sampling
                  (VMEM-resident PRNG + threshold; the paper's workload
                  as a memory-roofline-optimal TPU kernel)
flash_attention/  causal / sliding-window / GQA forward attention
                  (online softmax, banded block skip)

Each kernel ships kernel.py (pl.pallas_call + BlockSpec tiling),
ops.py (jit'd public wrapper) and ref.py (pure-jnp oracle); tests sweep
shapes/dtypes and assert against the oracle in interpret mode.
"""
