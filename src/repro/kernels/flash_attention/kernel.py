"""Pallas TPU flash-attention forward kernel (causal + sliding window + GQA).

Canonical Mosaic tiling: grid = (B*Hq, nQ, nK) with VMEM scratch carrying
the online-softmax state (m, l, acc) across the kK dimension:

  ki == 0        : init  m = -inf, l = 0, acc = 0
  every ki       : s = q k^T * scale; mask; online rescale; acc += p v
  ki == nK - 1   : out = acc / l     (0 where a row saw no valid key)

Blocks irrelevant under the causal/window band are skipped with pl.when --
the MXU work per q block is O(band width), which is what makes the
sliding-window archs (mixtral, h2o-danube) sub-quadratic and the 500k-token
decode shapes feasible.  (A production variant would shrink the grid to the
band instead of predicating; the predicated form keeps index maps rectangular
and is what we validate in interpret mode.  See EXPERIMENTS.md #Perf.)

GQA is expressed through the K/V index maps: q-head h reads kv-head
h // group, so K/V tiles are fetched once per group rather than repeated.

VMEM budget per grid point (f32): q (TQ, D) + k,v (TK, D) + acc (TQ, D)
+ m,l (TQ, 128).  Defaults TQ = TK = 256, D <= 256 => < 2 MiB, leaving
room for double buffering on a 16 MiB core.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_TQ = 256
DEFAULT_TK = 256


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: int, offset: int, kv_len: int,
    tq: int, tk: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Band test at block granularity (static offset, traced block ids).
    q_lo = qi * tq + offset
    q_hi = q_lo + tq - 1
    k_lo = ki * tk
    relevant = k_lo < kv_len
    if causal:
        relevant &= k_lo <= q_hi
    if window and window > 0:
        relevant &= (k_lo + tk - 1) > (q_lo - window)

    @pl.when(relevant)
    def _accumulate():
        q = q_ref[0].astype(jnp.float32)  # (TQ, D)
        k = k_ref[0].astype(jnp.float32)  # (TK, D)
        v = v_ref[0].astype(jnp.float32)  # (TK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (TQ, TK)

        q_ids = q_lo + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
        k_ids = k_lo + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
        mask = k_ids < kv_len
        if causal:
            mask &= k_ids <= q_ids
        if window and window > 0:
            mask &= (q_ids - k_ids) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]                       # (TQ, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)   # (TQ, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                      # (TQ, TK)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)              # (TQ, 1)
        l_new = l_scr[:, :1] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[:, :1]
        safe = jnp.maximum(l, 1e-30)
        o_ref[0] = (acc_scr[...] / safe * (l > 0)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "group", "scale", "causal", "window", "kv_len", "offset", "tq", "tk",
        "interpret",
    ),
)
def flash_attention_call(
    q: jax.Array,  # (B*Hq, Tq_pad, D)
    k: jax.Array,  # (B*Hkv, Tk_pad, D)
    v: jax.Array,  # (B*Hkv, Tk_pad, D)
    *,
    group: int,
    scale: float,
    causal: bool,
    window: int,
    kv_len: int,
    offset: int,   # kv_len - true_q_len (decode alignment)
    tq: int = DEFAULT_TQ,
    tk: int = DEFAULT_TK,
    interpret: bool = True,
) -> jax.Array:
    BH, Tq, D = q.shape
    Tk = k.shape[1]
    grid = (BH, Tq // tq, Tk // tk)
    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window,
        offset=offset, kv_len=kv_len, tq=tq, tk=tk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, D), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, tk, D), lambda h, qi, ki: (h // group, ki, 0)),
            pl.BlockSpec((1, tk, D), lambda h, qi, ki: (h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, D), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, 128), jnp.float32),
            pltpu.VMEM((tq, 128), jnp.float32),
            pltpu.VMEM((tq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
