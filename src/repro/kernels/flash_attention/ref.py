"""Pure-jnp oracle for the flash-attention kernel.

Dense softmax attention with causal / sliding-window / GQA semantics
identical to the kernel: query position i (global index ``i + offset``
where ``offset = kv_len - q_len``) may attend key j iff

    j <= i + offset                         (causal)
    and (window <= 0 or i + offset - j < window)   (sliding window)
    and j < kv_len                          (key padding)

Softmax is computed in float32 regardless of input dtype.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,  # (B, Hq, Tq, D)
    k: jax.Array,  # (B, Hkv, Tk, D)
    v: jax.Array,  # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
) -> jax.Array:
    B, Hq, Tq, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D**0.5)
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32))
    s = s * scale
    Tk = k.shape[2]
    offset = Tk - Tq
    qi = jnp.arange(Tq)[:, None] + offset
    kj = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= kj <= qi
    if window and window > 0:
        mask &= qi - kj < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid key (possible with tiny windows) -> zeros
    any_valid = mask.any(-1)[None, None, :, None]
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    out = jnp.where(any_valid, out, 0.0)
    return out.astype(q.dtype)
