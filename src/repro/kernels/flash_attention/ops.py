"""Public jit'd wrapper for the flash-attention kernel.

Accepts (B, Hq, Tq, D) / (B, Hkv, Tk, D) tensors, handles GQA flattening,
seq padding to tile multiples, and decode alignment (Tq < Tk means the
queries are the *last* Tq positions).  ``interpret=True`` (default) runs the
kernel body on CPU for validation; the TPU launcher flips it off.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_TK, DEFAULT_TQ, flash_attention_call


def _pad_axis(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "tq", "tk", "interpret")
)
def flash_attention(
    q: jax.Array,  # (B, Hq, Tq, D)
    k: jax.Array,  # (B, Hkv, Tk, D)
    v: jax.Array,  # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    window: int = 0,
    tq: int = DEFAULT_TQ,
    tk: int = DEFAULT_TK,
    interpret: bool = True,
) -> jax.Array:
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    group = Hq // Hkv
    scale = 1.0 / (D**0.5)
    tq_eff = min(tq, _round_up(Tq))
    tk_eff = min(tk, _round_up(Tk))

    qf = _pad_axis(q.reshape(B * Hq, Tq, D), tq_eff, 1)
    kf = _pad_axis(k.reshape(B * Hkv, Tk, D), tk_eff, 1)
    vf = _pad_axis(v.reshape(B * Hkv, Tk, D), tk_eff, 1)

    out = flash_attention_call(
        qf, kf, vf,
        group=group, scale=scale, causal=causal, window=window,
        kv_len=Tk, offset=Tk - Tq, tq=tq_eff, tk=tk_eff, interpret=interpret,
    )
    return out[:, :Tq].reshape(B, Hq, Tq, D)


def _round_up(n: int, mult: int = 128) -> int:
    return ((n + mult - 1) // mult) * mult
