"""Name -> engine factory registry.

Consumers construct samplers exclusively through here (``make_engine``), so
backends are interchangeable everywhere a name is accepted:

    >>> eng = make_engine("jax-bucketed", {0: 1.0, 1: 3.0}, c=1.0, seed=0)

Legacy method names from the paper benchmarks ("DIPS", "R-ODSS", ...)
resolve as aliases of the host engines, keeping old call sites and saved
benchmark configs working.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

from ..core.pps import Key
from .base import SamplerEngine

Factory = Callable[..., SamplerEngine]


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    name: str
    kind: str  # "host" | "device"
    factory: Factory
    description: str = ""


_REGISTRY: Dict[str, EngineSpec] = {}
_ALIASES: Dict[str, str] = {}


def register_engine(
    name: str,
    kind: str,
    factory: Factory,
    description: str = "",
    aliases: Tuple[str, ...] = (),
) -> None:
    if kind not in ("host", "device"):
        raise ValueError(f"kind must be 'host' or 'device', got {kind!r}")
    key = name.lower()
    if key in _REGISTRY:
        raise ValueError(f"engine {name!r} already registered")
    _REGISTRY[key] = EngineSpec(name=name, kind=kind, factory=factory,
                                description=description)
    for a in aliases:
        _ALIASES[a.lower()] = key


def get_spec(name: str) -> EngineSpec:
    key = name.lower()
    key = _ALIASES.get(key, key)
    try:
        return _REGISTRY[key]
    except KeyError:
        raise KeyError(
            f"unknown engine {name!r}; available: {', '.join(sorted(_REGISTRY))}"
        ) from None


def make_engine(
    name: str,
    items: Optional[Dict[Key, float]] = None,
    c: float = 1.0,
    seed: Optional[int] = None,
    **kwargs,
) -> SamplerEngine:
    """Construct a registered engine over the instance <items, c>."""
    return get_spec(name).factory(items, c=c, seed=seed, **kwargs)


def available_engines(kind: Optional[str] = None) -> Tuple[str, ...]:
    """Canonical engine names, optionally filtered by kind."""
    return tuple(
        spec.name for key, spec in sorted(_REGISTRY.items())
        if kind is None or spec.kind == kind
    )


def engine_kind(name: str) -> str:
    return get_spec(name).kind
