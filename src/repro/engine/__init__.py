"""repro.engine: unified dynamic-sampling subsystem.

One protocol (``SamplerEngine``), one registry, interchangeable backends:

  ============== ====== ==================================================
  name           kind   implementation
  ============== ====== ==================================================
  host-dips      host   ``core.DIPS`` (paper Sec 3; O(1) query + update)
  host-rodss     host   ``core.R_ODSS`` (SS reduction; O(n) PPS update)
  host-rbss      host   ``core.R_BSS``
  host-rhss      host   ``core.R_HSS``
  host-brute     host   ``core.BruteForcePPS`` (O(n) query, O(1) update)
  jax-flat       device ``core.jax_sampler.pps_sample_indices``
  jax-bucketed   device ``DynamicBucketedIndex`` over ``BucketedIndex``
  jax-sharded    device slot-sharded bucketed sampler (``shard_map``)
  pallas-mask    device fused Pallas kernel (interpret mode off-TPU)
  ============== ====== ==================================================

Legacy benchmark names ("DIPS", "R-ODSS", "R-BSS", "R-HSS", "BruteForce")
alias the host engines.  Construct with ``make_engine(name, items, c=c,
seed=seed)``; enumerate with ``available_engines(kind=...)``.
"""

from __future__ import annotations

import functools

from .base import SamplerEngine, SlotTable, rng_from_key
from .registry import (
    EngineSpec,
    available_engines,
    engine_kind,
    get_spec,
    make_engine,
    register_engine,
)
from .host import HostEngine

register_engine(
    "host-dips", "host", functools.partial(HostEngine, method="DIPS"),
    description="paper-faithful DIPS index: O(1) query, O(1) update",
    aliases=("DIPS", "dips"),
)
register_engine(
    "host-rodss", "host", functools.partial(HostEngine, method="R-ODSS"),
    description="SS reduction to ODSS: O(1) query, O(n) PPS update",
    aliases=("R-ODSS",),
)
register_engine(
    "host-rbss", "host", functools.partial(HostEngine, method="R-BSS"),
    description="SS reduction to BringmannSS: static, O(n) update",
    aliases=("R-BSS",),
)
register_engine(
    "host-rhss", "host", functools.partial(HostEngine, method="R-HSS"),
    description="SS reduction to HeterogeneousSS: O(log n + mu) query",
    aliases=("R-HSS",),
)
register_engine(
    "host-brute", "host", functools.partial(HostEngine, method="BruteForce"),
    description="dynamic array + full scan: O(n) query, O(1) update",
    aliases=("BruteForce",),
)

# jax is a hard dependency of repro.core (the host path imports it too),
# so device backends register unconditionally.
from .device import BucketedJaxEngine, FlatJaxEngine, PallasMaskEngine
from .dynamic_bucketed import DynamicBucketedIndex
from .sharded import ShardedBucketedEngine
from .spec import SnapshotSpec, size_class, spec_for

register_engine(
    "jax-flat", "device", FlatJaxEngine,
    description="flat Bernoulli-mask compaction: Theta(B*n), batched",
)
register_engine(
    "jax-bucketed", "device", BucketedJaxEngine,
    description="dynamic bucketed index: Theta(B*b*c) candidates, batched",
)
register_engine(
    "jax-sharded", "device", ShardedBucketedEngine,
    description="slot-sharded bucketed sampler: shard_map per-shard draws, "
                "one psum for the global total",
)
register_engine(
    "pallas-mask", "device", PallasMaskEngine,
    description="fused Pallas mask kernel (TPU PRNG; CPU interpret)",
)

from .gradient import gradient_sampler, register_gradient_sampler  # noqa: E402

__all__ = [
    "SamplerEngine",
    "SlotTable",
    "HostEngine",
    "EngineSpec",
    "register_engine",
    "make_engine",
    "get_spec",
    "available_engines",
    "engine_kind",
    "rng_from_key",
    "gradient_sampler",
    "register_gradient_sampler",
    "FlatJaxEngine",
    "BucketedJaxEngine",
    "PallasMaskEngine",
    "ShardedBucketedEngine",
    "DynamicBucketedIndex",
    "SnapshotSpec",
    "size_class",
    "spec_for",
]
