"""SnapshotSpec: static-shape size classes for device snapshots.

``core.jax_index.bucketed_sample`` (and every other jitted program over a
``BucketedIndex``) specializes on the array shapes ``(n, m)`` of the
snapshot.  A dynamic workload changes the live size on every structural
rebuild, so without intervention steady-state churn retraces and
recompiles XLA programs where the paper's index pays microseconds --
the O(1)-update claim dies in the compile queue.

The fix is the device-native analogue of the paper's structural
partitioning: quantize every snapshot build to a *size class*.  A
``SnapshotSpec`` records the live sizes (``n_live``, ``m_real``) and the
power-of-two padded sizes (``n_pad``, ``m_pad``) the arrays are built at.
Padding is probability-exact by construction:

  * padded element slots carry weight 0 and live in padded buckets whose
    ``bucket_count`` is 0, so the Poisson candidate rate of every padded
    bucket is ``count * mu = 0`` -- a padded id can never be drawn;
  * padded bucket bounds are positive (they repeat the last real bound)
    so the thinning ratio ``log1p(-p)/(-mu)`` stays finite even for the
    clamped out-of-range candidate slots of invalid lanes;
  * totals are true sums -- zero weights add nothing.

Any sequence of rebuilds whose live sizes stay inside one size class
therefore reuses one compiled program per (batch, cap) shape; the
``DeviceEngine.compile_cache_misses`` counter observes exactly the
class/shape transitions.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

#: Smallest padded sizes; tiny pools all land in one class instead of
#: recompiling through 1, 2, 4, ... as they warm up.
MIN_N_PAD = 64
MIN_M_PAD = 8


def size_class(x: int, floor: int) -> int:
    """Smallest power of two >= max(x, floor)."""
    c = max(int(floor), 1)
    x = int(x)
    while c < x:
        c <<= 1
    return c


class SnapshotSpec(NamedTuple):
    """Shape contract of one padded device snapshot."""

    n_live: int  # live elements actually present
    n_pad: int   # element-axis length the arrays are built at (pow2)
    m_real: int  # occupied weight buckets
    m_pad: int   # bucket-axis length the arrays are built at (pow2)
    b: int       # bucket base (weight ratio per bucket)

    @property
    def shape_class(self) -> Tuple[int, int, int]:
        """The compile-relevant part: two snapshots with equal
        ``shape_class`` lower to byte-identical programs."""
        return (self.n_pad, self.m_pad, self.b)

    def holds(self, n_live: int, m_real: int) -> bool:
        """Would a rebuild at (n_live, m_real) stay in this class?"""
        return n_live <= self.n_pad and m_real <= self.m_pad


def spec_for(
    n_live: int,
    m_real: int,
    b: int,
    *,
    min_n: int = MIN_N_PAD,
    min_m: int = MIN_M_PAD,
) -> SnapshotSpec:
    """Quantize live sizes up to their power-of-two size class."""
    return SnapshotSpec(
        n_live=int(n_live),
        n_pad=size_class(n_live, min_n),
        m_real=int(m_real),
        m_pad=size_class(m_real, min_m),
        b=int(b),
    )


