"""Registry of gradient-sparsification samplers (train-side consumers).

``train.compression`` used to hard-code ``pps_gradient_mask``; it now
resolves its sampler here by name, so alternative samplers plug into the
same CompressionConfig without touching the trainer:

  * "pps"  -- Poisson pi-ps over |g| (unbiased; the paper's operator).
  * "topk" -- deterministic magnitude top-k (biased; classic baseline for
    ablations -- with error feedback it still converges).

A sampler is ``fn(key, grads, k) -> (sparsified, keep_mask)`` with expected
(or exact) kept count k, jit-safe.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..core.jax_sampler import pps_gradient_mask

GradientSampler = Callable[
    [jax.Array, jax.Array, jax.Array], Tuple[jax.Array, jax.Array]
]

_GRADIENT_SAMPLERS: Dict[str, GradientSampler] = {}


def register_gradient_sampler(name: str, fn: GradientSampler) -> None:
    if name in _GRADIENT_SAMPLERS:
        raise ValueError(f"gradient sampler {name!r} already registered")
    _GRADIENT_SAMPLERS[name] = fn


def gradient_sampler(name: str) -> GradientSampler:
    try:
        return _GRADIENT_SAMPLERS[name]
    except KeyError:
        raise KeyError(
            f"unknown gradient sampler {name!r}; "
            f"available: {', '.join(sorted(_GRADIENT_SAMPLERS))}"
        ) from None


def topk_gradient_mask(
    key: jax.Array, grads: jax.Array, k: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Deterministic magnitude top-k (biased; no rescale)."""
    g = grads.reshape(-1)
    kk = jnp.clip(jnp.asarray(k, jnp.float32), 1.0, g.size).astype(jnp.int32)
    thresh = -jnp.sort(-jnp.abs(g))[kk - 1]
    keep = jnp.abs(g) >= thresh
    out = jnp.where(keep, g, 0.0)
    return out.reshape(grads.shape), keep.reshape(grads.shape)


register_gradient_sampler("pps", pps_gradient_mask)
register_gradient_sampler("topk", topk_gradient_mask)
