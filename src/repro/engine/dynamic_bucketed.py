"""DynamicBucketedIndex: dynamic updates for the device-side bucket index.

``core.jax_index.BucketedIndex`` is a frozen snapshot; the paper's index is
dynamic.  This layer closes the gap with the same amortization argument as
Algorithm 4:

  * **In-bucket ``change_w``** keeps the bucket decomposition valid, so k
    buffered updates are applied as ONE device scatter
    (``bucketed_change_w_batch``) right before the next sample -- O(1)
    amortized per update, no rebuild, no host/device divergence.
  * **Structural updates** (insert, delete, cross-bucket ``change_w``) are
    absorbed into the host-side dense weight array (the logical truth) at
    O(1) cost each and only *marked*; the snapshot rebuild is deferred to
    the next sample, so a burst of U structural updates costs exactly ONE
    O(n log n) rebuild no matter how large U is.  The delta state is
    bounded by construction (a slot appears in the dirty set at most
    once), mirroring how Algorithm 4 batches work into the doubling-rule
    rebuild instead of paying per operation.
  * **Sampling** always flushes first, so ``sample`` draws from a device
    snapshot *consistent* with the logical state -- callers never manage a
    resync by hand (the pre-engine API forced exactly that).  Consistency
    has a worst case: a workload that alternates one structural update
    with one query rebuilds per query; the amortization pays off in the
    update-burst regimes the paper benchmarks (churn phases between
    sampling phases).  Incremental structural device updates are a
    ROADMAP item ("fixed-shape device snapshots").

Slots with weight 0 are simply absent from the snapshot, which lets the
engine layer recycle slots without index knowledge.

Snapshots are built at ``SnapshotSpec`` size classes (``engine.spec``):
the element and bucket axes are padded to powers of two, so every rebuild
whose live sizes stay inside the current class reuses the compiled
``bucketed_sample``/``bucketed_change_w_at`` programs -- steady-state
churn runs recompile-free.  Every device-program launch is reported
through ``on_program`` (signature = program name + compile-relevant
shapes) so the engine layer can count compile-cache misses.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..core.jax_index import (
    BucketedIndex,
    bucket_ids,
    bucketed_change_w_at,
    bucketed_sample,
    build_bucketed_index,
    marginal_probs,
)
from .spec import SnapshotSpec, spec_for


class DynamicBucketedIndex:
    """Bounded delta buffer over a rebuilt ``BucketedIndex`` snapshot."""

    def __init__(
        self,
        weights: np.ndarray,
        b: int = 4,
        on_program: Optional[Callable[[tuple], None]] = None,
    ) -> None:
        self.b = b
        self._w = np.asarray(weights, np.float64).copy()
        self._on_program = on_program or (lambda sig: None)
        self.spec: Optional[SnapshotSpec] = None
        self.rebuild_count = -1  # the initial build is not an amortized cost
        self._rebuild()

    # -- snapshot (re)construction ------------------------------------------
    def _rebuild(self) -> None:
        live = np.nonzero(self._w > 0.0)[0]
        self._live_slots = live.astype(np.int32)
        # compact-id -> slot lookup incl. sentinel, cached here because it
        # is O(n_live) to build and only changes on rebuild
        self._lut = np.append(self._live_slots, np.int32(self._w.size))
        self._slot_to_compact = {int(s): i for i, s in enumerate(live)}
        if live.size:
            buckets = bucket_ids(self._w[live], self.b)
            self.spec = spec_for(live.size, np.unique(buckets).size, self.b)
            self.index: Optional[BucketedIndex] = build_bucketed_index(
                self._w[live], b=self.b,
                n_pad=self.spec.n_pad, m_pad=self.spec.m_pad, j=buckets,
            )
            self._bucket_at_build = buckets
            # compact-id -> sorted-position inverse, cached so each delta
            # flush is an O(k) positional scatter instead of an O(n) invert
            ids = np.asarray(self.index.sorted_ids)
            inv = np.empty(ids.size, np.int32)
            inv[ids] = np.arange(ids.size, dtype=np.int32)
            self._compact_to_pos = inv
        else:
            self.index = None
            self.spec = None
            self._bucket_at_build = np.zeros(0, np.int64)
            self._compact_to_pos = np.zeros(0, np.int32)
        self._n_live = int(live.size)
        self._structural = 0
        self._dirty: set = set()
        self._inbucket: Dict[int, float] = {}
        self._scatter_flushes = 0
        self.rebuild_count += 1

    def _note_structural(self, slot: int) -> None:
        # O(1): mark only.  The rebuild is deferred to the next flush() --
        # rebuilding eagerly mid-burst would produce snapshots that are
        # discarded before any sample ever reads them.
        self._dirty.add(slot)
        self._inbucket.pop(slot, None)
        self._structural += 1

    # -- dynamic operations (slot-level) -------------------------------------
    def _grow_to(self, slot: int) -> None:
        if slot >= self._w.size:
            new = np.zeros(max(slot + 1, 2 * self._w.size, 8), np.float64)
            new[: self._w.size] = self._w
            self._w = new

    def insert_slot(self, slot: int, w: float) -> None:
        self._grow_to(slot)
        self._w[slot] = w
        if w > 0.0:
            self._n_live += 1
            self._note_structural(slot)

    def delete_slot(self, slot: int) -> None:
        was_live = self._w[slot] > 0.0
        self._w[slot] = 0.0
        if was_live:
            self._n_live -= 1
            self._note_structural(slot)

    def change_w_slot(self, slot: int, w: float) -> None:
        w_old = self._w[slot]
        self._w[slot] = w
        if (w > 0.0) != (w_old > 0.0):
            self._n_live += 1 if w > 0.0 else -1
            self._note_structural(slot)
            return
        if w_old == 0.0:  # zero -> zero
            return
        compact = self._slot_to_compact.get(slot)
        if (
            compact is not None
            and slot not in self._dirty
            and bucket_ids(np.asarray([w]), self.b)[0]
            == self._bucket_at_build[compact]
        ):
            self._inbucket[slot] = w  # last write wins; one scatter later
        else:
            self._note_structural(slot)

    # -- flush ----------------------------------------------------------------
    def flush(self) -> None:
        """Make the device snapshot consistent with the logical state."""
        if self._structural > 0:
            self._rebuild()
            return
        if not self._inbucket or self.index is None:
            return
        slots = np.fromiter(self._inbucket.keys(), np.int64)
        ws = np.asarray([self._inbucket[int(s)] for s in slots], np.float64)
        pos = self._compact_to_pos[
            [self._slot_to_compact[int(s)] for s in slots]
        ]
        # One O(k) positional scatter for the whole delta batch.  (Distinct
        # delta sizes jit separate scatter programs; steady-state loops
        # flush a constant-size batch, so this caches after one step.)
        self._on_program(
            ("bucketed_change_w_at", self.spec.shape_class, int(pos.size)))
        new_index, ok = bucketed_change_w_at(
            self.index, jnp.asarray(pos), jnp.asarray(ws, jnp.float32)
        )
        self.index = new_index
        self._inbucket.clear()
        if not bool(np.all(np.asarray(ok))):
            # float boundary disagreement host vs device: rebuild to be safe
            self._rebuild()
            return
        # Each incremental f32 total update adds ~total*2^-24 rounding
        # error and nothing else corrects it in a pure in-bucket workload;
        # periodically recompute the exact sum to bound the drift.
        self._scatter_flushes += 1
        if self._scatter_flushes % 256 == 0:
            self.index = self.index._replace(
                total=jnp.sum(self.index.sorted_weights)
            )

    # -- sampling --------------------------------------------------------------
    def sample(
        self, key: jax.Array, batch: int, cap: int = 64, c: float = 1.0
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(slot_ids[B, cap], counts[B]); padding entries hold a value >=
        the number of slots (scatter-safe sentinel)."""
        self.flush()
        if self.index is None:
            return (
                np.full((batch, cap), int(self._w.size), np.int32),
                np.zeros(batch, np.int32),
            )
        self._on_program(
            ("bucketed_sample", self.spec.shape_class, batch, cap))
        ids, cnt = bucketed_sample(key, self.index, c, batch=batch, cap=cap)
        # zero-weight inserts grow _w without a rebuild; keep the padding
        # sentinel >= every live slot count (O(1), the rest of lut is valid)
        self._lut[-1] = np.int32(self._w.size)
        out = self._lut[np.minimum(np.asarray(ids), self._live_slots.size)]
        return out.astype(np.int32), np.asarray(cnt, np.int32)

    # -- introspection -----------------------------------------------------------
    @property
    def total(self) -> float:
        return float(self._w.sum())

    @property
    def n_live(self) -> int:
        return self._n_live

    def marginals(self, c: float = 1.0) -> np.ndarray:
        """Per-slot inclusion probability of the flushed device snapshot."""
        self.flush()
        out = np.zeros(self._w.size, np.float64)
        if self.index is not None:
            # marginal_probs is padded to n_pad; padded compact ids carry
            # exactly 0, the live prefix maps back through the slot lut
            probs = np.asarray(marginal_probs(self.index, c))
            out[self._live_slots] = probs[: self._live_slots.size]
        return out
