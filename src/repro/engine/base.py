"""``SamplerEngine``: one dynamic Poisson pi-ps sampling API, many backends.

The paper's index answers single queries in O(1) on a CPU; accelerators
answer *batches*.  Production wants both behind one interface so callers
(influence maximization, the data pipeline, benchmarks) never hard-code a
backend.  Every engine maintains the same *logical* dynamic instance
<S, w, c> and exposes:

  * ``query(rng)``                      -- one PPS subset as a list of keys.
  * ``query_batch(key, batch, cap)``    -- B independent subsets as padded
    (ids[B, cap], counts[B]) int32 arrays; ids are *slot* indices, stable
    across updates, decoded back to keys via ``decode_batch``/``slot_key``.
  * ``insert / delete / change_w``      -- dynamic updates (paper Alg 4).
  * ``inclusion_probability(key)``      -- c*w(v)/W of the logical state.
  * ``snapshot()``                      -- frozen ``PPSInstance`` of the
    logical state (ground truth for the host/device agreement tests).

Slot contract: each key occupies an integer slot for its whole lifetime;
slots of deleted keys are recycled.  Padding entries in ``query_batch``
hold ``pad_id`` (>= the number of slots) -- scatter-safe sentinels, same
convention as ``jax_sampler.pps_sample_indices``.

See DESIGN.md "Engine architecture" for the backend matrix and
``repro.engine.registry`` for construction by name.
"""

from __future__ import annotations

import abc
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from ..core.pps import Key, PPSInstance


def rng_from_key(key) -> np.random.Generator:
    """Derive a host Generator from a jax PRNG key (or a plain int seed).

    Host engines consume numpy randomness; device engines consume jax keys.
    ``query_batch`` takes the jax-style key everywhere so call sites stay
    backend-agnostic, and host backends fold it into a numpy seed here.
    """
    if key is None:
        return np.random.default_rng()
    if isinstance(key, (int, np.integer)):
        return np.random.default_rng(int(key))
    import jax

    data = np.asarray(jax.random.key_data(key)).ravel()
    return np.random.default_rng(data.astype(np.uint64))


class SlotTable:
    """Stable key <-> integer-slot mapping with slot recycling."""

    def __init__(self, keys: Iterable[Key] = ()) -> None:
        self.keys: List[Optional[Key]] = list(keys)
        self.key_to_slot: Dict[Key, int] = {k: i for i, k in enumerate(self.keys)}
        if len(self.key_to_slot) != len(self.keys):
            raise KeyError("duplicate keys")
        self.free: List[int] = []

    def __len__(self) -> int:
        return len(self.key_to_slot)

    @property
    def capacity(self) -> int:
        return len(self.keys)

    def slot(self, key: Key) -> int:
        return self.key_to_slot[key]

    def insert(self, key: Key) -> int:
        if key in self.key_to_slot:
            raise KeyError(f"duplicate key {key!r}")
        if self.free:
            s = self.free.pop()
            self.keys[s] = key
        else:
            s = len(self.keys)
            self.keys.append(key)
        self.key_to_slot[key] = s
        return s

    def delete(self, key: Key) -> int:
        s = self.key_to_slot.pop(key)
        self.keys[s] = None
        self.free.append(s)
        return s


class SamplerEngine(abc.ABC):
    """Abstract dynamic Poisson pi-ps sampler (see module docstring)."""

    #: "host" (numpy, O(1) single query) or "device" (jax, batched).
    kind: str = "host"
    #: True when query_batch is a native batched device program rather than
    #: a host loop -- benchmarks use this to pick timing strategy.
    NATIVE_BATCH: bool = False
    #: True when a single update forces an O(n) rebuild (SS-reduction
    #: baselines); benchmarks scale update counts down for these.
    UPDATE_REBUILDS: bool = False
    #: Number of XLA programs this engine has caused to compile (device
    #: engines count program-signature misses; host engines compile
    #: nothing, so the protocol-level answer is 0).  bench_churn and the
    #: CI perf gate read this uniformly across backends.
    compile_cache_misses: int = 0

    def __init__(self, items: Optional[Dict[Key, float]] = None, c: float = 1.0) -> None:
        if not (0.0 < c <= 1.0):
            raise ValueError(f"c must be in (0, 1], got {c}")
        self.c = c
        items = dict(items or {})
        self._weights: Dict[Key, float] = {k: float(w) for k, w in items.items()}
        self._slots = SlotTable(items.keys())

    # -- dynamic operations (shared bookkeeping; backends extend) -----------
    def insert(self, key: Key, w: float) -> None:
        self._check_weight(w)
        slot = self._slots.insert(key)
        self._weights[key] = float(w)
        self._insert_slot(slot, key, float(w))

    def delete(self, key: Key) -> float:
        w = self._weights.pop(key)
        slot = self._slots.delete(key)
        self._delete_slot(slot, key, w)
        return w

    def change_w(self, key: Key, w_new: float) -> None:
        self._check_weight(w_new)
        slot = self._slots.slot(key)  # raises on unknown key BEFORE mutating
        self._weights[key] = float(w_new)
        self._change_w_slot(slot, key, float(w_new))

    @staticmethod
    def _check_weight(w: float) -> None:
        if not (w >= 0.0) or np.isinf(w):
            raise ValueError(f"weights must be finite and >= 0, got {w}")

    # -- backend hooks -------------------------------------------------------
    @abc.abstractmethod
    def _insert_slot(self, slot: int, key: Key, w: float) -> None: ...

    @abc.abstractmethod
    def _delete_slot(self, slot: int, key: Key, w: float) -> None: ...

    @abc.abstractmethod
    def _change_w_slot(self, slot: int, key: Key, w: float) -> None: ...

    # -- queries -------------------------------------------------------------
    @abc.abstractmethod
    def query(self, rng: Optional[np.random.Generator] = None) -> List[Key]: ...

    @abc.abstractmethod
    def query_batch(
        self, key, batch: int, cap: int = 64
    ) -> Tuple[np.ndarray, np.ndarray]: ...

    # -- introspection -------------------------------------------------------
    def __len__(self) -> int:
        return len(self._weights)

    def __contains__(self, key: Key) -> bool:
        return key in self._weights

    def weight(self, key: Key) -> float:
        return self._weights[key]

    @property
    def total_weight(self) -> float:
        return float(sum(self._weights.values()))

    def inclusion_probability(self, key: Key) -> float:
        """c*w(v)/W of the *logical* state (matches host DIPS semantics;
        values may exceed 1 when c*w > W -- samplers clip at draw time)."""
        W = self.total_weight
        return 0.0 if W <= 0.0 else self.c * self._weights[key] / W

    def snapshot(self) -> PPSInstance:
        return PPSInstance(dict(self._weights), c=self.c)

    @property
    def pad_id(self) -> int:
        """Smallest sentinel: every padding entry in query_batch is >= this."""
        return self._slots.capacity

    def slot_key(self, slot: int) -> Key:
        k = self._slots.keys[slot]
        if k is None:
            raise KeyError(f"slot {slot} is empty")
        return k

    def decode_batch(
        self, ids: np.ndarray, counts: np.ndarray
    ) -> List[List[Key]]:
        """Map (ids, counts) from query_batch back to per-query key lists."""
        ids = np.asarray(ids)
        counts = np.asarray(counts)
        return [
            [self.slot_key(int(s)) for s in row[:c]]
            for row, c in zip(ids, counts)
        ]
