"""Device-side engines: batched JAX/Pallas samplers behind the dynamic API.

All three keep the logical weights in a dense host array indexed by slot
(weight 0 = empty slot, inclusion probability exactly 0) and lazily mirror
it to the device, so every dynamic operation is O(1) host work and the
device pays only when a query actually runs:

  * ``FlatJaxEngine``     -- ``pps_sample_indices`` over the dense vector;
    Theta(B*n) work, bandwidth-bound, trivially dynamic (scatter/resync).
  * ``BucketedJaxEngine`` -- ``DynamicBucketedIndex`` over the TPU-adapted
    bucket decomposition; expected Theta(B*b*c) candidates per batch and
    genuinely dynamic via the bounded delta buffer (no caller resync).
  * ``PallasMaskEngine``  -- the fused Pallas mask kernel
    (``kernels.pps_sample``); runs everywhere via interpret mode on CPU and
    the fused hardware-PRNG path on TPU.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..core.jax_sampler import mask_to_indices, pps_sample_indices
from ..core.pps import Key
from ..kernels.pps_sample.ops import pps_sample_mask
from . import spec as spec_mod
from .base import SamplerEngine
from .dynamic_bucketed import DynamicBucketedIndex


class DeviceEngine(SamplerEngine):
    """Shared dense-slot-array machinery for device backends."""

    kind = "device"
    NATIVE_BATCH = True

    def __init__(
        self,
        items: Optional[Dict[Key, float]] = None,
        c: float = 1.0,
        seed: Optional[int] = None,
    ) -> None:
        super().__init__(items, c=c)
        self._rng = np.random.default_rng(seed)
        self._program_signatures: set = set()
        self.compile_cache_misses = 0
        cap = max(self._slots.capacity, 1)
        self._wnp = np.zeros(cap, np.float64)
        for k, w in self._weights.items():
            self._wnp[self._slots.slot(k)] = w
        self._post_init()

    def _post_init(self) -> None:  # backends override
        pass

    # -- compile-cache accounting ---------------------------------------------
    def _note_program(self, sig: tuple) -> None:
        """Record one device-program launch.

        ``sig`` must contain exactly the compile-relevant facts (program
        name + static shapes); a signature not seen before means XLA had
        to trace and compile, so ``compile_cache_misses`` counts the
        recompiles a workload pays.  Size-class padding (engine/spec.py)
        exists precisely so steady-state churn keeps this flat after
        warmup -- benchmarks/bench_paper.py:bench_churn asserts it.
        """
        if sig not in self._program_signatures:
            self._program_signatures.add(sig)
            self.compile_cache_misses += 1

    # -- dense array upkeep ---------------------------------------------------
    def _set_slot(self, slot: int, w: float) -> None:
        if slot >= self._wnp.size:
            new = np.zeros(max(slot + 1, 2 * self._wnp.size), np.float64)
            new[: self._wnp.size] = self._wnp
            self._wnp = new
        self._wnp[slot] = w

    def _insert_slot(self, slot: int, key: Key, w: float) -> None:
        self._set_slot(slot, w)

    def _delete_slot(self, slot: int, key: Key, w: float) -> None:
        self._set_slot(slot, 0.0)

    def _change_w_slot(self, slot: int, key: Key, w: float) -> None:
        self._set_slot(slot, w)

    @property
    def total_weight(self) -> float:
        return float(self._wnp.sum())

    def marginals(self) -> np.ndarray:
        """Per-slot inclusion probability of the state query_batch samples."""
        W = self._wnp.sum()
        return self._wnp / W * self.c if W > 0 else np.zeros_like(self._wnp)

    # -- single query via the batched path ------------------------------------
    def query(self, rng: Optional[np.random.Generator] = None) -> List[Key]:
        rng = rng if rng is not None else self._rng
        key = jax.random.key(int(rng.integers(np.iinfo(np.int64).max)))
        ids, counts = self.query_batch(key, 1)
        return self.decode_batch(ids, counts)[0]


class DenseMirrorEngine(DeviceEngine):
    """Device engines whose snapshot is just the dense weight vector,
    mirrored to the device lazily (any update invalidates, the next query
    resyncs once).  The mirror is zero-padded to its power-of-two size
    class (engine/spec.py): weight 0 means inclusion probability exactly
    0, so padding is free, and slot-array growth inside one class reuses
    the compiled program."""

    def _post_init(self) -> None:
        self._dev: Optional[jax.Array] = None

    def _set_slot(self, slot: int, w: float) -> None:
        super()._set_slot(slot, w)
        self._dev = None  # resynced lazily at the next query

    def _device_weights(self) -> jax.Array:
        if self._dev is None:
            n_pad = spec_mod.size_class(self._wnp.size, spec_mod.MIN_N_PAD)
            padded = np.zeros(n_pad, np.float32)
            padded[: self._wnp.size] = self._wnp
            self._dev = jnp.asarray(padded)
        return self._dev


class FlatJaxEngine(DenseMirrorEngine):
    def query_batch(
        self, key, batch: int, cap: int = 64
    ) -> Tuple[np.ndarray, np.ndarray]:
        w = self._device_weights()
        self._note_program(("pps_sample_indices", w.shape[0], batch, cap))
        ids, cnt = pps_sample_indices(key, w, self.c, batch=batch, cap=cap)
        return np.asarray(ids), np.asarray(cnt)


class BucketedJaxEngine(DeviceEngine):
    """Delegates the dense slot array entirely to its DynamicBucketedIndex
    (one copy of the weights, one growth path)."""

    def __init__(self, items=None, c: float = 1.0, seed: Optional[int] = None,
                 b: int = 4) -> None:
        self._dbi_opts = dict(b=b)
        super().__init__(items, c=c, seed=seed)

    def _post_init(self) -> None:
        self._dbi = DynamicBucketedIndex(
            self._wnp, on_program=self._note_program, **self._dbi_opts)
        del self._wnp  # single source of truth is _dbi._w from here on

    def _insert_slot(self, slot: int, key: Key, w: float) -> None:
        self._dbi.insert_slot(slot, w)

    def _delete_slot(self, slot: int, key: Key, w: float) -> None:
        self._dbi.delete_slot(slot)

    def _change_w_slot(self, slot: int, key: Key, w: float) -> None:
        self._dbi.change_w_slot(slot, w)

    @property
    def total_weight(self) -> float:
        return self._dbi.total

    def query_batch(
        self, key, batch: int, cap: int = 64
    ) -> Tuple[np.ndarray, np.ndarray]:
        return self._dbi.sample(key, batch, cap=cap, c=self.c)

    def marginals(self) -> np.ndarray:
        return self._dbi.marginals(self.c)

    @property
    def rebuild_count(self) -> int:
        return self._dbi.rebuild_count


class PallasMaskEngine(DenseMirrorEngine):
    """Fused mask kernel; interpret-mode on CPU, fused PRNG on TPU."""

    def _post_init(self) -> None:
        super()._post_init()
        on_tpu = jax.default_backend() == "tpu"
        self._fused = on_tpu
        self._interpret = not on_tpu

    def query_batch(
        self, key, batch: int, cap: int = 64
    ) -> Tuple[np.ndarray, np.ndarray]:
        w = self._device_weights()
        self._note_program(("pps_sample_mask", w.shape[0], batch, cap))
        mask = pps_sample_mask(
            key, w, self.c, batch=batch,
            fused_rng=self._fused, interpret=self._interpret,
        )
        ids, counts = mask_to_indices(mask.astype(bool), cap=cap)
        return np.asarray(ids), np.asarray(counts)
