"""jax-sharded: slot-partitioned bucketed sampling across a device mesh.

A single-HBM dense slot vector caps pool size; this engine removes the
wall by partitioning *slots* across the mesh (``sharding.slot_mesh``) and
running the bucketed candidate draw per shard under ``shard_map``:

  1. Live slots are dealt round-robin onto the ``D`` mesh devices, and
     each shard builds its own padded ``BucketedIndex`` over its local
     weights -- all shards share one ``SnapshotSpec`` size class, so the
     per-shard arrays stack into ``(D, ...)`` tensors sharded on the
     leading axis and every rebuild inside the class reuses one compiled
     program (counted by ``DeviceEngine.compile_cache_misses``).
  2. Inside ``shard_map`` each device draws its local Poisson candidates
     exactly as ``bucketed_sample`` does, except the acceptance target
     ``p_v = c*w_v/W`` uses the *global* total obtained with ONE ``psum``
     -- inclusion events are independent per element, and the shards hold
     disjoint elements, so the union over shards is exactly the Poisson
     pi-ps law of the whole pool.
  3. Per-shard results map through a local->global slot lut on device,
     then the ``(D, B, cap)`` candidates are gather-compacted into the
     engine's standard padded ``(ids[B, cap], counts[B])`` contract (the
     shard axis folds into the cap axis and one sort per row pushes the
     sentinel padding right).

Dynamic updates follow the same amortization as the rest of the device
path: O(1) host-side writes mark the snapshot dirty, and a burst of U
updates costs one sharded rebuild at the next query.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.jax_index import (
    BucketedIndex,
    bucket_ids,
    bucketed_sample,
    build_bucketed_index,
)
from ..core.pps import Key
from ..sharding import slot_mesh
from .device import DeviceEngine
from .spec import MIN_M_PAD, MIN_N_PAD, SnapshotSpec, size_class


@functools.partial(
    jax.jit, static_argnames=("batch", "cap", "mesh", "axis", "b"))
def _sharded_sample(
    key: jax.Array,
    stacked: Tuple[jax.Array, ...],  # 7 BucketedIndex fields, leading dim D
    lut: jax.Array,                  # (D, n_pad + 1) local compact -> global slot
    c: float,
    *,
    batch: int,
    cap: int,
    mesh: Mesh,
    axis: str,
    b: int,
) -> Tuple[jax.Array, jax.Array]:
    """One device program: per-shard bucketed draws + psum + compaction."""

    def body(sw, sid, bstart, bcount, bwbar, blo, btot, lut_s):
        # each arg arrives as the (1, ...) block of this shard
        local = BucketedIndex(
            sorted_weights=sw[0], sorted_ids=sid[0], bucket_start=bstart[0],
            bucket_count=bcount[0], bucket_wbar=bwbar[0], bucket_lo=blo[0],
            # ONE collective: the global total that turns local weights
            # into globally correct inclusion probabilities c*w/W
            total=jax.lax.psum(btot[0], axis), b=b,
        )
        shard = jax.lax.axis_index(axis)
        ids, cnt = bucketed_sample(
            jax.random.fold_in(key, shard), local, c, batch=batch, cap=cap)
        # local compact ids (sentinel n_pad included) -> global slot ids
        return lut_s[0][ids][None], cnt[None]

    ids, cnt = shard_map(
        body, mesh=mesh,
        in_specs=tuple([P(axis)] * 8),
        out_specs=(P(axis), P(axis)),
        check_rep=False,
    )(*stacked, lut)

    # gather-compact: fold the shard axis into the candidate axis; every
    # entry is a live global slot id or the sentinel (> every live id),
    # so one sort per row pushes real ids left and padding right
    flat = jnp.transpose(ids, (1, 0, 2)).reshape(batch, -1)
    compact = jnp.sort(flat, axis=1)[:, :cap]
    counts = jnp.minimum(jnp.sum(cnt, axis=0), cap).astype(jnp.int32)
    return compact.astype(jnp.int32), counts


def _empty_shard_index(n_pad: int, m_pad: int, b: int) -> BucketedIndex:
    """All-padding shard (more devices than live slots): every bucket has
    count 0, so the shard contributes zero candidates and zero total."""
    return BucketedIndex(
        sorted_weights=jnp.zeros(n_pad, jnp.float32),
        sorted_ids=jnp.arange(n_pad, dtype=jnp.int32),
        bucket_start=jnp.zeros(m_pad, jnp.int32),
        bucket_count=jnp.zeros(m_pad, jnp.int32),
        bucket_wbar=jnp.ones(m_pad, jnp.float32),
        bucket_lo=jnp.ones(m_pad, jnp.float32),
        total=jnp.asarray(0.0, jnp.float32),
        b=b,
    )


class ShardedBucketedEngine(DeviceEngine):
    """Slot-sharded dynamic engine (see module docstring)."""

    def __init__(
        self,
        items: Optional[Dict[Key, float]] = None,
        c: float = 1.0,
        seed: Optional[int] = None,
        b: int = 4,
        mesh: Optional[Mesh] = None,
    ) -> None:
        self.b = b
        self._mesh = mesh if mesh is not None else slot_mesh()
        self._axis = self._mesh.axis_names[0]
        self._num_shards = int(np.prod(self._mesh.devices.shape))
        super().__init__(items, c=c, seed=seed)

    def _post_init(self) -> None:
        self._snap: Optional[Tuple] = None
        self.rebuild_count = -1  # the initial build is not an amortized cost
        self.spec: Optional[SnapshotSpec] = None

    def _set_slot(self, slot: int, w: float) -> None:
        super()._set_slot(slot, w)
        self._snap = None  # O(1) mark; one rebuild at the next query

    # -- sharded snapshot ------------------------------------------------------
    def _shard_assignment(self, live: np.ndarray) -> list:
        """Deal live slots round-robin -> shard loads differ by <= 1."""
        return [live[s :: self._num_shards] for s in range(self._num_shards)]

    def _rebuild(self) -> None:
        live = np.nonzero(self._wnp > 0.0)[0].astype(np.int32)
        self.rebuild_count += 1
        if live.size == 0:
            self._snap = None
            self.spec = None
            self._has_live = False
            return
        self._has_live = True
        parts = self._shard_assignment(live)
        # one size class for all shards: the stacked (D, ...) arrays must
        # be rectangular, and a shared class means a rebuild only changes
        # the program when the *largest* shard crosses a class boundary
        js = [bucket_ids(self._wnp[p], self.b) if p.size else None
              for p in parts]
        m_reals = [len(np.unique(j)) if j is not None else 0 for j in js]
        n_pad = size_class(max(p.size for p in parts), MIN_N_PAD)
        m_pad = size_class(max(m_reals), MIN_M_PAD)
        built = [
            build_bucketed_index(
                self._wnp[p], b=self.b, n_pad=n_pad, m_pad=m_pad, j=j)
            if p.size
            else _empty_shard_index(n_pad, m_pad, self.b)
            for p, j in zip(parts, js)
        ]
        self.spec = SnapshotSpec(
            n_live=int(live.size), n_pad=n_pad,
            m_real=max(m_reals), m_pad=m_pad, b=self.b)

        sentinel = np.int32(self._wnp.size)
        luts = np.full((self._num_shards, n_pad + 1), sentinel, np.int32)
        for s, p in enumerate(parts):
            luts[s, : p.size] = p

        shard_spec = NamedSharding(self._mesh, P(self._axis))
        stacked = tuple(
            jax.device_put(
                jnp.stack([getattr(idx, f) for idx in built]), shard_spec)
            for f in ("sorted_weights", "sorted_ids", "bucket_start",
                      "bucket_count", "bucket_wbar", "bucket_lo", "total")
        )
        lut = jax.device_put(jnp.asarray(luts), shard_spec)
        self._snap = (stacked, lut, int(sentinel))

    # -- queries ---------------------------------------------------------------
    def query_batch(
        self, key, batch: int, cap: int = 64
    ) -> Tuple[np.ndarray, np.ndarray]:
        if self._snap is None:
            self._rebuild()
        if not self._has_live:
            return (
                np.full((batch, cap), self._wnp.size, np.int32),
                np.zeros(batch, np.int32),
            )
        stacked, lut, sentinel = self._snap
        self._note_program(
            ("sharded_sample", self._num_shards, self.spec.shape_class,
             batch, cap))
        ids, cnt = _sharded_sample(
            key, stacked, lut, self.c,
            batch=batch, cap=cap, mesh=self._mesh, axis=self._axis, b=self.b)
        return np.asarray(ids), np.asarray(cnt)

    # -- introspection ---------------------------------------------------------
    def mesh_layout(self) -> Dict[str, object]:
        """Human-readable shard layout (quickstart example, debugging)."""
        if self._snap is None:
            self._rebuild()
        live = np.nonzero(self._wnp > 0.0)[0]
        per_shard = [int(p.size) for p in self._shard_assignment(live)]
        return {
            "axis": self._axis,
            "num_shards": self._num_shards,
            "devices": [str(d) for d in self._mesh.devices.reshape(-1)],
            "live_slots_per_shard": per_shard,
            "size_class": None if self.spec is None else self.spec.shape_class,
        }
