"""Host-side engines: paper-faithful DIPS plus the Sec 4 competitors.

``HostEngine`` adapts any of the repo's host indexes (``repro.core.DIPS``
and the SS-reduction baselines) to the ``SamplerEngine`` protocol.  The
wrapped structures already implement O(1)/O(n) single queries and dynamic
updates; this layer adds the slot table and the batched-query facade
(a host loop -- same asymptotic cost as B single queries, which *is* the
host cost model; device engines override with one fused program).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import ALL_METHODS
from ..core.pps import Key
from .base import SamplerEngine, rng_from_key


class HostEngine(SamplerEngine):
    kind = "host"
    NATIVE_BATCH = False

    def __init__(
        self,
        items: Optional[Dict[Key, float]] = None,
        c: float = 1.0,
        seed: Optional[int] = None,
        method: str = "DIPS",
        **method_kwargs,
    ) -> None:
        super().__init__(items, c=c)
        ctor = ALL_METHODS[method]
        self.method = method
        self._impl = ctor(dict(items or {}), c=c, seed=seed, **method_kwargs)
        self.UPDATE_REBUILDS = bool(getattr(self._impl, "UPDATE_REBUILDS", False))

    # -- backend hooks -------------------------------------------------------
    def _insert_slot(self, slot: int, key: Key, w: float) -> None:
        self._impl.insert(key, w)

    def _delete_slot(self, slot: int, key: Key, w: float) -> None:
        self._impl.delete(key)

    def _change_w_slot(self, slot: int, key: Key, w: float) -> None:
        self._impl.change_w(key, w)

    # -- queries -------------------------------------------------------------
    def query(self, rng: Optional[np.random.Generator] = None) -> List[Key]:
        return self._impl.query(rng)

    def query_batch(
        self, key, batch: int, cap: int = 64
    ) -> Tuple[np.ndarray, np.ndarray]:
        rng = rng_from_key(key)
        pad = self.pad_id
        ids = np.full((batch, cap), pad, np.int32)
        counts = np.zeros(batch, np.int32)
        slot_of = self._slots.key_to_slot
        for i in range(batch):
            ks = self._impl.query(rng)
            m = min(len(ks), cap)
            counts[i] = m
            for j in range(m):
                ids[i, j] = slot_of[ks[j]]
        return ids, counts

    @property
    def total_weight(self) -> float:
        return float(self._impl.total_weight)
