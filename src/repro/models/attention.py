"""Grouped-query attention with RoPE, qk-norm, sliding windows and KV caches.

One implementation serves every attention-bearing arch:
  * MHA (deepseek: kv == heads), GQA (qwen3/mixtral/...), MQA (gemma: kv=1)
  * optional per-head RMS qk-norm (qwen3)
  * sliding-window masking (mixtral, h2o-danube) -- and ring-buffer KV
    caches sized to the window, which is what makes decode_32k/long_500k
    memory-feasible for SWA archs
  * decode: single-token query against the cache; prefill: bulk forward
    that also fills the cache

Memory discipline: bulk attention is *chunked* over query rows
(cfg.attn_chunk, lax.scan) so the live score buffer is (B, H, C, T) rather
than (B, H, T, T) -- the XLA analogue of flash attention's outer loop, and
the difference between 137 GB and <1 GB of temp per device at 4k train /
32k prefill.  Scores carry an explicit sharding constraint: kv-heads ->
"model" when divisible, else query-groups, else query rows (always
divisible by the 1024 chunk).  ``attn_impl='pallas'`` dispatches to the
flash kernel (repro.kernels.flash_attention) on TPU runs.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding.context import axis_size, constrain
from .common import KeyGen, apply_rope, dense_init, rms_norm, zeros_init

NEG_INF = -1e30


def init_attention(kg: KeyGen, cfg: ModelConfig, layers: int) -> Dict:
    d, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        "wq": dense_init(kg, (layers, d, H * Dh), ("layers", "embed", "heads_x_dim"), fan_in=d),
        "wk": dense_init(kg, (layers, d, K * Dh), ("layers", "embed", "kv_x_dim"), fan_in=d),
        "wv": dense_init(kg, (layers, d, K * Dh), ("layers", "embed", "kv_x_dim"), fan_in=d),
        "wo": dense_init(kg, (layers, H * Dh, d), ("layers", "heads_x_dim", "embed"), fan_in=H * Dh),
    }
    if cfg.qk_norm:
        p["q_norm"] = zeros_init((layers, Dh), ("layers", None))
        p["k_norm"] = zeros_init((layers, Dh), ("layers", None))
    return p


class KVCache(NamedTuple):
    """Per-layer-stacked cache in dot-friendly (L, B, Hkv, S, Dh) layout:
    the decode einsum contracts directly against the cache with no layout
    transpose, which would otherwise re-stream the entire multi-GB cache
    every step (#Perf iteration C1).  For SWA archs ``S == window`` and
    slots are written round-robin; absolute positions are reconstructed
    from ``pos`` so no position ring is stored."""

    k: jax.Array  # (L, B, Hkv, S, Dh)
    v: jax.Array  # (L, B, Hkv, S, Dh)


def cache_len(cfg: ModelConfig, max_len: int) -> int:
    if cfg.swa_window > 0:
        return min(cfg.swa_window, max_len)
    return max_len


def init_kv_cache(cfg: ModelConfig, layers: int, batch: int, max_len: int) -> KVCache:
    S = cache_len(cfg, max_len)
    shape = (layers, batch, cfg.n_kv_heads, S, cfg.hd)
    dt = cfg.cdtype
    return KVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt))


def _project_qkv(p: Dict, cfg: ModelConfig, x: jax.Array):
    B, T, d = x.shape
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = cfg.cdtype
    q = (x @ p["wq"].astype(dt)).reshape(B, T, H, Dh)
    k = (x @ p["wk"].astype(dt)).reshape(B, T, K, Dh)
    v = (x @ p["wv"].astype(dt)).reshape(B, T, K, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def _attn_shard_mode(K: int, G: int, Tq: int) -> str:
    """How to model-shard attention: kv-heads > query-groups > query rows.

    Crucially the SAME dimension must be constrained on the q operand, the
    score tensor and the output: a scores-only constraint makes GSPMD
    reshard across mismatched dims, and for non-divisible head counts it
    falls back to *involuntary full rematerialization* -- an all-gather of
    the global (B, K, G, Tq, Tk) tensor (412 GB/layer for granite train_4k;
    see EXPERIMENTS.md #Perf iteration A1)."""
    ms = axis_size("model")
    if ms <= 1:
        return "none"
    if K % ms == 0:
        return "kv"
    if G % ms == 0:
        return "group"
    if Tq % ms == 0:
        return "rows"
    return "none"


_Q_ENTRIES = {  # (B, Tq, K, G, Dh)
    "kv": ("__dp__", None, "model", None, None),
    "group": ("__dp__", None, None, "model", None),
    "rows": ("__dp__", "model", None, None, None),
    "none": ("__dp__", None, None, None, None),
}
_S_ENTRIES = {  # (B, K, G, Tq, Tk)
    "kv": ("__dp__", "model", None, None, None),
    "group": ("__dp__", None, "model", None, None),
    "rows": ("__dp__", None, None, "model", None),
    "none": ("__dp__", None, None, None, None),
}
_KV_ENTRIES = {  # (B, Tk, K, Dh)
    "kv": ("__dp__", None, "model", None),
    "group": ("__dp__", None, None, None),
    "rows": ("__dp__", None, None, None),
    "none": ("__dp__", None, None, None),
}


def _sdpa(
    q: jax.Array,  # (B, Tq, H, Dh)
    k: jax.Array,  # (B, Tk, Hkv, Dh)
    v: jax.Array,  # (B, Tk, Hkv, Dh)
    mask: jax.Array,  # (B|1, Tq, Tk) bool
    cfg: ModelConfig,
) -> jax.Array:
    B, Tq, H, Dh = q.shape
    K = k.shape[2]
    group = H // K
    mode = _attn_shard_mode(K, group, Tq)
    qg = q.reshape(B, Tq, K, group, Dh)
    # Constraint scope (#Perf iterations A1/A1b/A1c): when head counts
    # divide the model axis GSPMD already propagates a good sharding, and
    # forcing operand constraints only adds reshards (dense archs regressed
    # 0.69 -> 0.32 roofline fraction under the blanket version).  The full
    # operand-consistent set is needed exactly in "rows" mode, where the
    # scores-only constraint triggers involuntary full rematerialization
    # (412 GB/layer gathers) for non-divisible head counts.
    full_set = mode == "rows" and cfg.family in ("moe", "encdec")
    if full_set:
        qg = constrain(qg, *_Q_ENTRIES[mode])
        k = constrain(k, *_KV_ENTRIES[mode])
        v = constrain(v, *_KV_ENTRIES[mode])
    # bf16 operands, f32 accumulation: avoids materializing f32 copies of
    # the (potentially multi-GB) K/V tensors (see EXPERIMENTS.md #Perf).
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32)
    s = s * (Dh**-0.5)
    s = constrain(s, *_S_ENTRIES[mode])
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    p_attn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bkgqs,bskd->bqkgd", p_attn.astype(v.dtype), v,
        preferred_element_type=jnp.float32)
    o = o.reshape(B, Tq, H, Dh).astype(q.dtype)
    if full_set:
        # Return replicated-over-T: a seq-sharded residual stream leaks
        # into the MoE dispatch (rank cumsum over sharded T) and costs far
        # more in resharding than one gather of o (#Perf iteration A1b).
        o = constrain(o, "__dp__", None, None, None)
    return o


def _mask_for(q_pos: jax.Array, k_pos: jax.Array, causal: bool, window: int) -> jax.Array:
    qi = q_pos[:, None]
    kj = k_pos[None, :]
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        mask &= kj <= qi
    if window and window > 0:
        mask &= (qi - kj) < window
    return mask


def _sdpa_chunked(
    q: jax.Array, k: jax.Array, v: jax.Array,
    positions: jax.Array, cfg: ModelConfig, causal: bool,
) -> jax.Array:
    """Query-chunked attention (flash-style outer loop as a lax.scan)."""
    B, T, H, Dh = q.shape
    C = cfg.attn_chunk
    nC = T // C
    qc = q.reshape(B, nC, C, H, Dh).swapaxes(0, 1)   # (nC, B, C, H, Dh)
    pc = positions.reshape(nC, C)

    def body(_, xs):
        qi, pi = xs
        mask = _mask_for(pi, positions, causal, cfg.swa_window)
        return None, _sdpa(qi, k, v, mask[None], cfg)

    _, oc = jax.lax.scan(body, None, (qc, pc))
    return oc.swapaxes(0, 1).reshape(B, T, H, Dh)


def _bulk_sdpa(q, k, v, positions, cfg: ModelConfig, causal: bool) -> jax.Array:
    T = q.shape[1]
    if cfg.attn_impl == "pallas" and causal:
        from ..kernels.flash_attention.ops import flash_attention

        qt, kt, vt = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
        o = flash_attention(
            qt, kt, vt, causal=causal, window=cfg.swa_window,
            interpret=jax.default_backend() != "tpu")
        return jnp.swapaxes(o, 1, 2)
    if cfg.attn_chunk > 0 and T > cfg.attn_chunk and T % cfg.attn_chunk == 0:
        return _sdpa_chunked(q, k, v, positions, cfg, causal)
    mask = _mask_for(positions, positions, causal, cfg.swa_window)
    return _sdpa(q, k, v, mask[None], cfg)


def attention_forward(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,                       # (B, T, d)
    positions: jax.Array,               # (T,) absolute positions
    causal: bool = True,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> jax.Array:
    """Bulk (train / prefill / encoder) attention."""
    B, T, _ = x.shape
    dt = cfg.cdtype
    q, k, v = _project_qkv(p, cfg, x)
    if cross_kv is not None:
        k, v = cross_kv
        mask = jnp.ones((1, T, k.shape[1]), bool)
        o = _sdpa(q, k, v, mask, cfg)
        return o.reshape(B, T, -1) @ p["wo"].astype(dt)
    if cfg.rope_theta > 0:
        q = apply_rope(q, jnp.broadcast_to(positions, (B, T)), cfg.rope_theta)
        k = apply_rope(k, jnp.broadcast_to(positions, (B, T)), cfg.rope_theta)
    o = _bulk_sdpa(q, k, v, positions, cfg, causal)
    return o.reshape(B, T, -1) @ p["wo"].astype(dt)


def attention_prefill(
    p: Dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
    cache_k: jax.Array, cache_v: jax.Array,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Bulk forward that also returns the filled cache (last S slots)."""
    B, T, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x)
    if cfg.rope_theta > 0:
        q = apply_rope(q, jnp.broadcast_to(positions, (B, T)), cfg.rope_theta)
        k = apply_rope(k, jnp.broadcast_to(positions, (B, T)), cfg.rope_theta)
    o = _bulk_sdpa(q, k, v, positions, cfg, causal=True)
    dt = cfg.cdtype
    out = o.reshape(B, T, -1) @ p["wo"].astype(dt)
    kc = k.swapaxes(1, 2)  # -> (B, K, T, Dh) cache layout (one-time)
    vc = v.swapaxes(1, 2)
    S = cache_k.shape[2]
    if cfg.swa_window > 0 and T > S:
        # keep the last `window` keys, placed so slot = abs_pos % S
        tail_k, tail_v = kc[:, :, -S:], vc[:, :, -S:]
        start = (T - S) % S
        cache_k = jnp.roll(tail_k, shift=start, axis=2).astype(cache_k.dtype)
        cache_v = jnp.roll(tail_v, shift=start, axis=2).astype(cache_v.dtype)
    else:
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, kc.astype(cache_k.dtype), 0, 2)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, vc.astype(cache_v.dtype), 0, 2)
    return out, cache_k, cache_v


def _sdpa_cached(
    q: jax.Array,        # (B, 1, H, Dh)
    ck: jax.Array,       # (B, K, S, Dh) -- cache layout, no transpose
    cv: jax.Array,
    mask: jax.Array,     # (B, 1, S)
    cfg: ModelConfig,
) -> jax.Array:
    B, Tq, H, Dh = q.shape
    K = ck.shape[1]
    group = H // K
    qg = q.reshape(B, Tq, K, group, Dh)
    s = jnp.einsum("bqkgd,bksd->bkgqs", qg, ck, preferred_element_type=jnp.float32)
    s = s * (Dh**-0.5)
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    p_attn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bqkgd", p_attn.astype(cv.dtype), cv,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Tq, H, Dh).astype(q.dtype)


def attention_decode(
    p: Dict, cfg: ModelConfig, x: jax.Array,  # (B, 1, d)
    pos: jax.Array,                           # () int32 current position
    cache_k: jax.Array, cache_v: jax.Array,   # (B, Hkv, S, Dh)
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B = x.shape[0]
    S = cache_k.shape[2]
    q, k, v = _project_qkv(p, cfg, x)
    if cfg.rope_theta > 0:
        posb = jnp.broadcast_to(pos[None], (B, 1))
        q = apply_rope(q, posb, cfg.rope_theta)
        k = apply_rope(k, posb, cfg.rope_theta)
    slot = pos % S if cfg.swa_window > 0 else jnp.minimum(pos, S - 1)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.swapaxes(1, 2).astype(cache_k.dtype), (0, 0, slot, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.swapaxes(1, 2).astype(cache_v.dtype), (0, 0, slot, 0))
    # absolute position of each slot (ring reconstruction)
    idx = jnp.arange(S)
    if cfg.swa_window > 0:
        abs_pos = pos - ((slot - idx) % S)
    else:
        abs_pos = idx
    valid = (abs_pos >= 0) & (abs_pos <= pos)
    if cfg.swa_window > 0:
        valid &= (pos - abs_pos) < cfg.swa_window
    mask = jnp.broadcast_to(valid[None, None, :], (B, 1, S))
    o = _sdpa_cached(q, cache_k, cache_v, mask, cfg)
    dt = cfg.cdtype
    out = o.reshape(B, 1, -1) @ p["wo"].astype(dt)
    return out, cache_k, cache_v
