"""Unified decoder-only LM covering the dense / moe / ssm / hybrid families.

Layers are *scanned* (params stacked on a leading "layers" axis) so the HLO
is depth-independent -- essential for compiling 56-layer models against a
512-way mesh in the dry-run -- with optional per-block remat.

Three entry points, one per workload kind:
  * ``forward``       -- bulk causal forward (train / the prefill shapes)
  * ``prefill``       -- bulk forward that also fills the decode state
  * ``decode_step``   -- one token against the cached state

Family specifics:
  dense / vlm : attn + MLP            (vlm: stub patch embeddings prepended)
  moe         : attn + top-k MoE
  ssm (xlstm) : (P-1) mLSTM + 1 sLSTM per super-block, no FFN (d_ff = 0)
  hybrid      : parallel attn + mamba heads (Hymba), then MLP
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding.context import constrain
from .common import (
    KeyGen,
    Param,
    dense_init,
    rms_norm,
    zeros_init,
)
from .attention import (
    KVCache,
    attention_decode,
    attention_forward,
    attention_prefill,
    init_attention,
    init_kv_cache,
)
from .mlp import init_mlp, init_moe, mlp_forward, moe_forward
from .ssm import (
    MambaState,
    MLSTMState,
    SLSTMState,
    init_mamba,
    init_mlstm,
    init_slstm,
    mamba_forward,
    mamba_init_state,
    mlstm_forward,
    mlstm_init_state,
    slstm_forward,
    slstm_init_state,
)


class DecodeState(NamedTuple):
    pos: jax.Array                      # () int32 -- next position to write
    kv: Optional[KVCache] = None        # attention families
    mlstm: Optional[MLSTMState] = None  # stacked (n_super, P-1, ...) for ssm
    slstm: Optional[SLSTMState] = None  # stacked (n_super, ...)
    mamba: Optional[MambaState] = None  # stacked (L, ...) for hybrid
    aux: Optional[jax.Array] = None


# ------------------------------ init -----------------------------------------

def init_decoder(key: jax.Array, cfg: ModelConfig) -> Dict:
    kg = KeyGen(key)
    d, L, Vp = cfg.d_model, cfg.n_layers, cfg.vocab_padded
    params: Dict[str, Any] = {
        "embed": dense_init(kg, (Vp, d), ("vocab", "embed"), fan_in=1, scale=0.02),
        "final_norm": zeros_init((d,), ("embed",)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kg, (d, Vp), ("embed", "vocab"), fan_in=d)

    if cfg.family == "ssm":
        P = max(cfg.slstm_every, 2)
        n_super, rem = divmod(L, P)
        assert rem == 0, f"n_layers={L} must be a multiple of slstm_every={P}"
        sub_m = cfg.replace(n_heads=cfg.n_heads)  # same head layout
        blocks = {
            "mlstm": init_mlstm(kg, sub_m, n_super * (P - 1)),
            "mlstm_ln": zeros_init((n_super * (P - 1), d), ("layers", "embed")),
            "slstm": init_slstm(kg, sub_m, n_super),
            "slstm_ln": zeros_init((n_super, d), ("layers", "embed")),
        }
        # reshape mlstm stacks to (n_super, P-1, ...)
        def regroup(p):
            return Param(
                p.value.reshape((n_super, P - 1) + p.value.shape[1:]),
                ("layers_outer",) + p.axes,
            )
        blocks["mlstm"] = jax.tree.map(regroup, blocks["mlstm"], is_leaf=lambda x: isinstance(x, Param))
        blocks["mlstm_ln"] = regroup(blocks["mlstm_ln"])
        params["blocks"] = blocks
        return params

    blocks = {
        "ln1": zeros_init((L, d), ("layers", "embed")),
        "attn": init_attention(kg, cfg, L),
        "ln2": zeros_init((L, d), ("layers", "embed")),
    }
    if cfg.family == "hybrid":
        blocks["mamba"] = init_mamba(kg, cfg, L)
        blocks["attn_ln"] = zeros_init((L, d), ("layers", "embed"))
        blocks["mamba_ln"] = zeros_init((L, d), ("layers", "embed"))
    if cfg.is_moe:
        blocks["moe"] = init_moe(kg, cfg, L)
    elif cfg.mlp_kind != "none":
        blocks["mlp"] = init_mlp(kg, cfg, L)
    params["blocks"] = blocks
    return params


# ------------------------------ blocks ----------------------------------------

def _attn_block(bp: Dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
                aux: jax.Array) -> Tuple[jax.Array, jax.Array]:
    h = rms_norm(x, bp["ln1"])
    if cfg.family == "hybrid":
        a = attention_forward(bp["attn"], cfg, h, positions)
        m, _ = mamba_forward(bp["mamba"], cfg, h, mamba_init_state(cfg, x.shape[0]))
        mixed = 0.5 * (rms_norm(a, bp["attn_ln"]) + rms_norm(m, bp["mamba_ln"]))
        x = x + mixed
    else:
        x = x + attention_forward(bp["attn"], cfg, h, positions)
    h2 = rms_norm(x, bp["ln2"])
    if cfg.is_moe:
        out, a_loss = moe_forward(bp["moe"], cfg, h2)
        x = x + out
        aux = aux + a_loss
    elif cfg.mlp_kind != "none":
        x = x + mlp_forward(bp["mlp"], cfg, h2)
    return x, aux


def _ssm_superblock(bp: Dict, cfg: ModelConfig, x: jax.Array,
                    m_states: MLSTMState, s_state: SLSTMState
                    ) -> Tuple[jax.Array, MLSTMState, SLSTMState]:
    """(P-1) mLSTM layers (inner scan) then one sLSTM layer."""

    def m_layer(carry, xs):
        xc = carry
        lp, st = xs
        h = rms_norm(xc, lp["__ln__"])
        out, st_new = mlstm_forward({k: v for k, v in lp.items() if k != "__ln__"},
                                    cfg, h, st)
        return xc + out, st_new

    ml = dict(bp["mlstm"])
    ml["__ln__"] = bp["mlstm_ln"]
    x, new_m = jax.lax.scan(m_layer, x, (ml, m_states))
    h = rms_norm(x, bp["slstm_ln"])
    out, new_s = slstm_forward(bp["slstm"], cfg, h, s_state)
    return x + out, new_m, new_s


# ------------------------------ bulk forward -----------------------------------

def _embed(params: Dict, cfg: ModelConfig, tokens: jax.Array,
           extra_embeds: Optional[jax.Array]) -> jax.Array:
    x = params["embed"][tokens].astype(cfg.cdtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, cfg.cdtype)
    if extra_embeds is not None:  # vlm stub frontend: prepend patch embeds
        x = jnp.concatenate([extra_embeds.astype(cfg.cdtype), x], axis=1)
    return constrain(x, "__dp__", None, None)


def _unembed(params: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"])
    if cfg.tie_embeddings:
        logits = x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    else:
        logits = x.astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
    return constrain(logits, "__dp__", None, "model")


def forward(
    params: Dict,
    cfg: ModelConfig,
    tokens: jax.Array,                        # (B, T_text)
    extra_embeds: Optional[jax.Array] = None,  # (B, n_patches, d) for vlm
) -> Tuple[jax.Array, jax.Array]:
    """Bulk causal forward.  Returns (logits (B, T, V_pad), aux_loss)."""
    x = _embed(params, cfg, tokens, extra_embeds)
    B, T, _ = x.shape
    positions = jnp.arange(T)
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.family == "ssm":
        P = max(cfg.slstm_every, 2)
        n_super = cfg.n_layers // P

        def super_block(carry, bp):
            xc = carry
            m0 = _stack_states(mlstm_init_state(cfg, B), P - 1)
            s0 = slstm_init_state(cfg, B)
            out, _, _ = _ssm_superblock(bp, cfg, xc, m0, s0)
            return out, None

        body = super_block
        if cfg.remat == "block":
            body = jax.checkpoint(super_block, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["blocks"], unroll=cfg.scan_unroll)
        return _unembed(params, cfg, x), aux0

    def block(carry, bp):
        xc, aux = carry
        xc, aux = _attn_block(bp, cfg, xc, positions, aux)
        return (xc, aux), None

    body = block
    if cfg.remat == "block":
        body = jax.checkpoint(block, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"], unroll=cfg.scan_unroll)
    return _unembed(params, cfg, x), aux


def _stack_states(state, n: int):
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), state)


# ------------------------------ decode ------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> DecodeState:
    pos = jnp.zeros((), jnp.int32)
    if cfg.family == "ssm":
        P = max(cfg.slstm_every, 2)
        n_super = cfg.n_layers // P
        m = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_super, P - 1) + a.shape),
            mlstm_init_state(cfg, batch),
        )
        s = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_super,) + a.shape),
            slstm_init_state(cfg, batch),
        )
        return DecodeState(pos=pos, mlstm=m, slstm=s)
    kv = init_kv_cache(cfg, cfg.n_layers, batch, max_len)
    if cfg.family == "hybrid":
        mam = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape),
            mamba_init_state(cfg, batch),
        )
        return DecodeState(pos=pos, kv=kv, mamba=mam)
    return DecodeState(pos=pos, kv=kv)


def prefill(
    params: Dict, cfg: ModelConfig, tokens: jax.Array, state: DecodeState,
    extra_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, DecodeState]:
    """Bulk forward filling the decode state; returns last-position logits."""
    x = _embed(params, cfg, tokens, extra_embeds)
    B, T, _ = x.shape
    positions = jnp.arange(T)

    if cfg.family == "ssm":
        def super_block(carry, xs):
            xc = carry
            bp, m_st, s_st = xs
            out, m_new, s_new = _ssm_superblock(bp, cfg, xc, m_st, s_st)
            return out, (m_new, s_new)

        x, (m_all, s_all) = jax.lax.scan(
            super_block, x, (params["blocks"], state.mlstm, state.slstm),
            unroll=cfg.scan_unroll)
        logits = _unembed(params, cfg, x[:, -1:])
        return logits, state._replace(pos=jnp.asarray(T, jnp.int32), mlstm=m_all, slstm=s_all)

    def block(carry, xs):
        xc = carry
        bp, ck, cv, mam = xs
        h = rms_norm(xc, bp["ln1"])
        if cfg.family == "hybrid":
            a, ck, cv = attention_prefill(bp["attn"], cfg, h, positions, ck, cv)
            m_out, mam = mamba_forward(bp["mamba"], cfg, h, mam)
            xc = xc + 0.5 * (rms_norm(a, bp["attn_ln"]) + rms_norm(m_out, bp["mamba_ln"]))
        else:
            a, ck, cv = attention_prefill(bp["attn"], cfg, h, positions, ck, cv)
            xc = xc + a
        h2 = rms_norm(xc, bp["ln2"])
        if cfg.is_moe:
            out, _ = moe_forward(bp["moe"], cfg, h2)
            xc = xc + out
        elif cfg.mlp_kind != "none":
            xc = xc + mlp_forward(bp["mlp"], cfg, h2)
        return xc, (ck, cv, mam)

    mam_in = state.mamba if state.mamba is not None else _dummy_mamba(cfg, B)
    x, (ck_all, cv_all, mam_all) = jax.lax.scan(
        block, x, (params["blocks"], state.kv.k, state.kv.v, mam_in),
        unroll=cfg.scan_unroll)
    logits = _unembed(params, cfg, x[:, -1:])
    new_state = state._replace(
        pos=jnp.asarray(T, jnp.int32), kv=KVCache(ck_all, cv_all),
        mamba=mam_all if state.mamba is not None else None,
    )
    return logits, new_state


def _dummy_mamba(cfg: ModelConfig, batch: int):
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape),
        MambaState(jnp.zeros((batch, 1, 1), jnp.float32)),
    )


def decode_step(
    params: Dict, cfg: ModelConfig, token: jax.Array, state: DecodeState,
) -> Tuple[jax.Array, DecodeState]:
    """One decode step.  token: (B, 1) int32 -> logits (B, 1, V_pad)."""
    x = params["embed"][token].astype(cfg.cdtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, cfg.cdtype)
    B = x.shape[0]
    pos = state.pos

    if cfg.family == "ssm":
        def super_block(carry, xs):
            xc = carry
            bp, m_st, s_st = xs
            out, m_new, s_new = _ssm_superblock(bp, cfg, xc, m_st, s_st)
            return out, (m_new, s_new)

        x, (m_all, s_all) = jax.lax.scan(
            super_block, x, (params["blocks"], state.mlstm, state.slstm),
            unroll=cfg.scan_unroll)
        return _unembed(params, cfg, x), state._replace(
            pos=pos + 1, mlstm=m_all, slstm=s_all)

    def block(carry, xs):
        xc = carry
        bp, ck, cv, mam = xs
        h = rms_norm(xc, bp["ln1"])
        a, ck, cv = attention_decode(bp["attn"], cfg, h, pos, ck, cv)
        if cfg.family == "hybrid":
            m_out, mam = mamba_forward(bp["mamba"], cfg, h, mam)
            xc = xc + 0.5 * (rms_norm(a, bp["attn_ln"]) + rms_norm(m_out, bp["mamba_ln"]))
        else:
            xc = xc + a
        h2 = rms_norm(xc, bp["ln2"])
        if cfg.is_moe:
            out, _ = moe_forward(bp["moe"], cfg, h2)
            xc = xc + out
        elif cfg.mlp_kind != "none":
            xc = xc + mlp_forward(bp["mlp"], cfg, h2)
        return xc, (ck, cv, mam)

    mam_in = state.mamba if state.mamba is not None else _dummy_mamba(cfg, B)
    x, (ck_all, cv_all, mam_all) = jax.lax.scan(
        block, x, (params["blocks"], state.kv.k, state.kv.v, mam_in),
        unroll=cfg.scan_unroll)
    new_state = state._replace(
        pos=pos + 1, kv=KVCache(ck_all, cv_all),
        mamba=mam_all if state.mamba is not None else None,
    )
    return _unembed(params, cfg, x), new_state
