"""Model bundle: one uniform interface over all architecture families.

``build_model(cfg)`` returns a ``Model`` whose methods are pure functions
(usable under jit/pjit/eval_shape):

  init(key)                          -> tagged params (Param leaves)
  loss(params, batch)                -> (loss, metrics)        [train shapes]
  forward(params, batch)             -> logits                 [prefill shapes]
  init_state(batch, max_len)         -> decode state
  prefill(params, batch, state)      -> (logits, state)
  decode(params, token, state)       -> (logits, state)
  input_specs(shape)                 -> ShapeDtypeStruct pytree for dry-runs

Batch layout (ShapeDtypeStruct stand-ins come from ``input_specs``):
  dense/moe/ssm/hybrid: tokens (B,T) i32, labels (B,T) i32
  vlm:   + patch_embeds (B, n_patches, d) bf16; tokens/labels (B, T-n_patches)
  audio: frames (B, enc_seq, d) bf16; tokens/labels (B, T)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from .common import cross_entropy_loss, split_params, unwrap
from . import decoder as dec
from . import encdec


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    forward: Callable
    init_state: Callable
    prefill: Callable
    decode: Callable
    input_specs: Callable


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        return _build_encdec(cfg)
    return _build_decoder(cfg)


# ------------------------------ decoder families ------------------------------

def _build_decoder(cfg: ModelConfig) -> Model:
    is_vlm = cfg.family == "vlm" and cfg.n_patches > 0

    def init(key):
        return dec.init_decoder(key, cfg)

    def loss(params, batch):
        params = unwrap(params)
        extra = batch.get("patch_embeds") if is_vlm else None
        logits, aux = dec.forward(params, cfg, batch["tokens"], extra)
        labels = batch["labels"]
        if is_vlm:  # loss only on text positions (after the patch prefix)
            logits = logits[:, cfg.n_patches :]
        l, metrics = cross_entropy_loss(logits, labels, vocab_size=cfg.vocab_size)
        metrics["aux_loss"] = aux
        return l + aux, metrics

    def forward(params, batch):
        params = unwrap(params)
        extra = batch.get("patch_embeds") if is_vlm else None
        logits, _ = dec.forward(params, cfg, batch["tokens"], extra)
        return logits

    def init_state(batch, max_len):
        return dec.init_decode_state(cfg, batch, max_len)

    def prefill(params, batch, state):
        params = unwrap(params)
        extra = batch.get("patch_embeds") if is_vlm else None
        return dec.prefill(params, cfg, batch["tokens"], state, extra)

    def decode(params, token, state):
        return dec.decode_step(unwrap(params), cfg, token, state)

    def input_specs(shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        B, T = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "decode":
            return {"token": jax.ShapeDtypeStruct((B, 1), i32)}
        t_text = T - cfg.n_patches if is_vlm else T
        specs = {"tokens": jax.ShapeDtypeStruct((B, t_text), i32)}
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, t_text), i32)
        if is_vlm:
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), cfg.cdtype)
        return specs

    return Model(cfg, init, loss, forward, init_state, prefill, decode, input_specs)


# ------------------------------ encoder-decoder --------------------------------

def _build_encdec(cfg: ModelConfig) -> Model:
    def init(key):
        return encdec.init_encdec(key, cfg)

    def loss(params, batch):
        params = unwrap(params)
        logits, aux = encdec.forward(params, cfg, batch["frames"], batch["tokens"])
        l, metrics = cross_entropy_loss(logits, batch["labels"], vocab_size=cfg.vocab_size)
        metrics["aux_loss"] = aux
        return l + aux, metrics

    def forward(params, batch):
        params = unwrap(params)
        logits, _ = encdec.forward(params, cfg, batch["frames"], batch["tokens"])
        return logits

    def init_state(batch, max_len):
        return encdec.init_state(cfg, batch, max_len)

    def prefill(params, batch, state):
        params = unwrap(params)
        return encdec.prefill(params, cfg, batch["frames"], batch["tokens"], state)

    def decode(params, token, state):
        return encdec.decode_step(unwrap(params), cfg, token, state)

    def input_specs(shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        B, T = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "decode":
            return {"token": jax.ShapeDtypeStruct((B, 1), i32)}
        specs = {
            "frames": jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), cfg.cdtype),
            "tokens": jax.ShapeDtypeStruct((B, T), i32),
        }
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, T), i32)
        return specs

    return Model(cfg, init, loss, forward, init_state, prefill, decode, input_specs)


def abstract_params(model: Model, key: Optional[jax.Array] = None):
    """Shape/axes of the parameter tree without allocating (for dry-runs)."""
    key = key if key is not None else jax.random.key(0)
    tagged = jax.eval_shape(model.init, key)
    return tagged


def param_count(tree: Any) -> int:
    vals = unwrap(tree)
    return sum(int(np_prod(x.shape)) for x in jax.tree.leaves(vals))


def np_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out
