"""Shared model machinery: tagged parameters, norms, RoPE, losses.

Every parameter is created as a ``Param(value, axes)`` leaf where ``axes``
names each dimension with a *logical* axis ("layers", "embed", "ffn",
"heads", "kv_heads", "head_dim", "vocab", "experts", ...).  The sharding
layer (repro.sharding) maps logical axes onto mesh axes; models never
mention mesh axes directly.  ``split_params`` separates values from axes so
the value tree is a plain pytree for jit/opt/checkpoint.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
class Param:
    """Array + logical-axis names.  Registered as a pytree node whose *aux
    data* carries the axes, so tagged trees pass through jit / grad /
    optimizers / eval_shape unchanged while the sharding layer can read the
    axes back from any derived tree (grads, moments, ...)."""

    __slots__ = ("value", "axes")

    def __init__(self, value: jax.Array, axes: Tuple[Optional[str], ...]) -> None:
        self.value = value
        self.axes = tuple(axes)

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)

    def __repr__(self) -> str:
        shape = getattr(self.value, "shape", None)
        return f"Param(shape={shape}, axes={self.axes})"


def is_param(x: Any) -> bool:
    return isinstance(x, Param)


def unwrap(tree: Any) -> Any:
    """Strip Param wrappers -> plain array tree (same values, no copies)."""
    return jax.tree.map(lambda p: p.value if is_param(p) else p, tree, is_leaf=is_param)


def axes_of(tree: Any) -> Any:
    """Tree of logical-axes tuples at each Param position (None elsewhere)."""
    return jax.tree.map(
        lambda p: p.axes if is_param(p) else None, tree, is_leaf=is_param
    )


def split_params(tree: Any) -> Tuple[Any, Any]:
    """(values, axes) with identical tree structure."""
    return unwrap(tree), axes_of(tree)


class KeyGen:
    """Deterministic fold-in key stream for parameter init."""

    def __init__(self, key: jax.Array) -> None:
        self._key = key
        self._i = 0

    def __call__(self) -> jax.Array:
        self._i += 1
        return jax.random.fold_in(self._key, self._i)


def dense_init(
    kg: KeyGen,
    shape: Tuple[int, ...],
    axes: Tuple[Optional[str], ...],
    *,
    fan_in: Optional[int] = None,
    scale: float = 1.0,
    dtype: jnp.dtype = jnp.float32,
) -> Param:
    """Truncated-normal fan-in init (std = scale / sqrt(fan_in))."""
    if fan_in is None:
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / math.sqrt(max(fan_in, 1))
    value = std * jax.random.truncated_normal(kg(), -2.0, 2.0, shape, dtype)
    return Param(value, axes)


def zeros_init(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.zeros(shape, dtype), axes)


def ones_init(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.ones(shape, dtype), axes)


# -- norms (always f32 math) ---------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) + bias.astype(
        jnp.float32
    )
    return out.astype(dt)


# -- rotary embeddings --------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, D); positions: broadcastable to (..., T)."""
    D = x.shape[-1]
    freqs = rope_frequencies(D, theta)  # (D/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., T, 1, D/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(T: int, d: int, offset: int = 0) -> jax.Array:
    pos = jnp.arange(offset, offset + T, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / d))
    pe = jnp.zeros((T, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# -- losses ---------------------------------------------------------------------

def cross_entropy_loss(
    logits: jax.Array,  # (B, T, V) -- may include padded vocab tail
    labels: jax.Array,  # (B, T) int32
    mask: Optional[jax.Array] = None,  # (B, T) 1 = count
    vocab_size: Optional[int] = None,
    z_loss: float = 1e-4,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Stable softmax xent in f32 with optional z-loss; ignores vocab padding."""
    V = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    if vocab_size is not None and vocab_size < V:
        pad_mask = jnp.arange(V) >= vocab_size
        lf = jnp.where(pad_mask[None, None, :], -1e30, lf)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    metrics = {
        "loss": loss,
        "ppl_tokens": denom,
        "accuracy": ((jnp.argmax(lf, -1) == labels) * mask).sum() / denom,
    }
    return loss, metrics


def cast_fp(x: jax.Array, dtype) -> jax.Array:
    return x.astype(dtype) if x.dtype != dtype else x
