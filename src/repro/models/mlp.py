"""Feed-forward blocks: SwiGLU / GeGLU / GELU MLPs and token-choice MoE.

The MoE uses capacity-bounded gather dispatch (argsort by expert, take the
first C tokens per expert) rather than one-hot einsum dispatch, so the
compiled FLOPs reflect *active* expert compute (top_k/E of dense) -- this is
what makes the mixtral / granite roofline numbers meaningful.  Dispatch is
vmapped over the batch row so the sort never crosses the data-parallel
sharding boundary (no global collectives from routing; see DESIGN.md).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding.context import constrain
from .common import KeyGen, dense_init


def init_mlp(kg: KeyGen, cfg: ModelConfig, layers: int) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(kg, (layers, d, f), ("layers", "embed", "ffn"), fan_in=d),
            "w_up": dense_init(kg, (layers, d, f), ("layers", "embed", "ffn"), fan_in=d),
            "w_down": dense_init(kg, (layers, f, d), ("layers", "ffn", "embed"), fan_in=f),
        }
    if cfg.mlp_kind == "gelu":
        return {
            "w_in": dense_init(kg, (layers, d, f), ("layers", "embed", "ffn"), fan_in=d),
            "w_out": dense_init(kg, (layers, f, d), ("layers", "ffn", "embed"), fan_in=f),
        }
    raise ValueError(cfg.mlp_kind)


def mlp_forward(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    dt = cfg.cdtype
    if cfg.mlp_kind in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_kind == "swiglu" else jax.nn.gelu
        g = act(x @ p["w_gate"].astype(dt))
        u = x @ p["w_up"].astype(dt)
        return (g * u) @ p["w_down"].astype(dt)
    h = jax.nn.gelu(x @ p["w_in"].astype(dt))
    return h @ p["w_out"].astype(dt)


# -- mixture of experts ---------------------------------------------------------

def init_moe(kg: KeyGen, cfg: ModelConfig, layers: int) -> Dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": dense_init(kg, (layers, d, E), ("layers", "embed", "experts"), fan_in=d),
        "w_gate": dense_init(kg, (layers, E, d, f), ("layers", "experts", "embed", "expert_ffn"), fan_in=d),
        "w_up": dense_init(kg, (layers, E, d, f), ("layers", "experts", "embed", "expert_ffn"), fan_in=d),
        "w_down": dense_init(kg, (layers, E, f, d), ("layers", "experts", "expert_ffn", "embed"), fan_in=f),
    }


def _dispatch_one_row(
    x: jax.Array,        # (T, d)
    gates: jax.Array,    # (T, E) combine weights (0 for unrouted)
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Capacity-bounded gather dispatch for one batch row.

    Returns (gathered (E, C, d), token_idx (E, C), combine_w (E, C)).
    Tokens beyond capacity C are dropped (standard token-choice semantics).
    """
    T, E = gates.shape
    C = max(1, int(cfg.top_k * T * cfg.capacity_factor / cfg.n_experts))
    C = min(C, T)
    routed = gates > 0.0  # (T, E)
    # rank of each token within its expert's queue (arrival order)
    ranks = jnp.cumsum(routed.astype(jnp.int32), axis=0) - 1  # (T, E)
    keep = routed & (ranks < C)
    t_idx = jnp.broadcast_to(jnp.arange(T)[:, None], (T, E)).reshape(-1)
    e_sc = jnp.where(keep, jnp.arange(E)[None, :], E).reshape(-1)   # E => dropped
    r_sc = jnp.where(keep, ranks, C).reshape(-1)                    # C => dropped
    slot_owner = jnp.full((E, C), T, jnp.int32).at[e_sc, r_sc].set(t_idx, mode="drop")
    combine_w = (
        jnp.zeros((E, C), gates.dtype).at[e_sc, r_sc].set(gates.reshape(-1), mode="drop")
    )
    x_pad = jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)], axis=0)
    gathered = x_pad[slot_owner]  # (E, C, d)
    return gathered, slot_owner, combine_w


def moe_forward(
    p: Dict, cfg: ModelConfig, x: jax.Array  # (B, T, d)
) -> Tuple[jax.Array, jax.Array]:
    """Token-choice top-k MoE; returns (out, aux_load_balance_loss)."""
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    dt = cfg.cdtype
    # Dispatch ranks are a cumsum over T: keep T unsharded here (batch rows
    # already carry the data parallelism), see #Perf iteration A1b.
    x = constrain(x, "__dp__", None, None)
    logits = (x @ p["router"].astype(dt)).astype(jnp.float32)  # (B, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)  # (B, T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    gates = jax.vmap(
        lambda i, w: jnp.zeros((T, E), probs.dtype).at[jnp.arange(T)[:, None], i].set(w)
    )(top_i, top_w)

    from ..sharding.context import axis_size

    # Small per-expert FFNs (granite: 512) keep weights replicated (see
    # sharding rule); shard the *capacity* dim over "model" instead so the
    # expert compute still splits 16 ways and the only collective is one
    # late (B, T, d) psum per layer (#Perf iteration A2b).
    ms = axis_size("model")
    cap_sharded = ms > 1 and cfg.d_ff // ms < 128

    def one_row(xr, gr):
        gathered, owner, comb = _dispatch_one_row(xr, gr.astype(dt), cfg)
        if cap_sharded and gathered.shape[1] % ms == 0:
            gathered = constrain(gathered, None, "model", None)
        h = jax.nn.silu(
            jnp.einsum("ecd,edf->ecf", gathered, p["w_gate"].astype(dt))
        ) * jnp.einsum("ecd,edf->ecf", gathered, p["w_up"].astype(dt))
        y = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))  # (E, C, d)
        y = y * comb[..., None].astype(dt)
        out = jnp.zeros((T + 1, d), dt)
        out = out.at[owner.reshape(-1)].add(y.reshape(-1, d), mode="drop")
        return out[:T]

    out = jax.vmap(one_row)(x, gates)
    # Late reduction: constrain the *combined* (B, T, d) output rather than
    # the (B, E, C, d) capacity tensor, so GSPMD psums after the scatter-add
    # (T vs E*C ~ top_k*capacity_factor x fewer bytes; #Perf iteration B1).
    out = constrain(out, "__dp__", None, None)
    # Switch-style load-balance aux loss
    me = probs.mean(axis=(0, 1))                    # (E,)
    ce = gates.astype(jnp.float32).mean(axis=(0, 1)) * E / max(k, 1)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)
    return out, aux
