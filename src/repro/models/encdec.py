"""Whisper-style encoder-decoder (audio family).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, enc_seq, d_model), which pass through a
linear adapter and the bidirectional encoder stack.  The decoder is a
causal transformer with per-layer cross-attention; positions are sinusoidal
(whisper uses absolute embeddings, not RoPE).

Decode state carries (self-KV ring, cross-KV computed once at prefill).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import KeyGen, dense_init, rms_norm, sinusoidal_positions, zeros_init
from .attention import (
    KVCache,
    attention_decode,
    attention_forward,
    attention_prefill,
    init_attention,
    init_kv_cache,
)
from .mlp import init_mlp, mlp_forward


class EncDecState(NamedTuple):
    pos: jax.Array
    self_kv: KVCache
    cross_k: jax.Array  # (L, B, S_enc, H, Dh)
    cross_v: jax.Array


def init_encdec(key: jax.Array, cfg: ModelConfig) -> Dict:
    kg = KeyGen(key)
    d, Vp = cfg.d_model, cfg.vocab_padded
    Le, Ld = cfg.n_enc_layers, cfg.n_layers
    params: Dict[str, Any] = {
        "adapter": dense_init(kg, (d, d), ("embed", "embed_out"), fan_in=d),
        "embed": dense_init(kg, (Vp, d), ("vocab", "embed"), fan_in=1, scale=0.02),
        "enc": {
            "ln1": zeros_init((Le, d), ("layers", "embed")),
            "attn": init_attention(kg, cfg, Le),
            "ln2": zeros_init((Le, d), ("layers", "embed")),
            "mlp": init_mlp(kg, cfg, Le),
        },
        "enc_norm": zeros_init((d,), ("embed",)),
        "dec": {
            "ln1": zeros_init((Ld, d), ("layers", "embed")),
            "self_attn": init_attention(kg, cfg, Ld),
            "ln2": zeros_init((Ld, d), ("layers", "embed")),
            "cross_q": dense_init(kg, (Ld, d, cfg.n_heads * cfg.hd),
                                  ("layers", "embed", "heads_x_dim"), fan_in=d),
            "cross_k": dense_init(kg, (Ld, d, cfg.n_heads * cfg.hd),
                                  ("layers", "embed", "heads_x_dim"), fan_in=d),
            "cross_v": dense_init(kg, (Ld, d, cfg.n_heads * cfg.hd),
                                  ("layers", "embed", "heads_x_dim"), fan_in=d),
            "cross_o": dense_init(kg, (Ld, cfg.n_heads * cfg.hd, d),
                                  ("layers", "heads_x_dim", "embed"), fan_in=cfg.n_heads * cfg.hd),
            "ln3": zeros_init((Ld, d), ("layers", "embed")),
            "mlp": init_mlp(kg, cfg, Ld),
        },
        "final_norm": zeros_init((d,), ("embed",)),
    }
    return params


def encode(params: Dict, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, d) stub embeddings -> encoder output (B, S_enc, d)."""
    dt = cfg.cdtype
    x = frames.astype(dt) @ params["adapter"].astype(dt)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(dt)[None]
    positions = jnp.arange(x.shape[1])

    def block(carry, bp):
        xc = carry
        h = rms_norm(xc, bp["ln1"])
        xc = xc + attention_forward(bp["attn"], cfg, h, positions, causal=False)
        h2 = rms_norm(xc, bp["ln2"])
        xc = xc + mlp_forward(bp["mlp"], cfg, h2)
        return xc, None

    body = jax.checkpoint(block, prevent_cse=False) if cfg.remat == "block" else block
    x, _ = jax.lax.scan(body, x, params["enc"], unroll=cfg.scan_unroll)
    return rms_norm(x, params["enc_norm"])


def _cross_attn(bp: Dict, cfg: ModelConfig, x: jax.Array,
                ck: jax.Array, cv: jax.Array) -> jax.Array:
    """q from x against precomputed per-layer cross K/V (B, S_enc, H, Dh)."""
    B, T, _ = x.shape
    H, Dh = cfg.n_heads, cfg.hd
    dt = cfg.cdtype
    q = (x @ bp["cross_q"].astype(dt)).reshape(B, T, H, Dh)
    s = jnp.einsum("bqhd,bshd->bhqs", q, ck, preferred_element_type=jnp.float32)
    s = s * (Dh**-0.5)
    p_attn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqs,bshd->bqhd", p_attn.astype(cv.dtype), cv,
                   preferred_element_type=jnp.float32).astype(dt)
    return o.reshape(B, T, H * Dh) @ bp["cross_o"].astype(dt)


def _project_cross_kv(params: Dict, cfg: ModelConfig, enc_out: jax.Array
                      ) -> Tuple[jax.Array, jax.Array]:
    """All-layer cross K/V from encoder output: (L, B, S, H, Dh) each."""
    dt = cfg.cdtype
    H, Dh = cfg.n_heads, cfg.hd
    B, S, d = enc_out.shape
    ck = jnp.einsum("bsd,lde->lbse", enc_out.astype(dt), params["dec"]["cross_k"].astype(dt))
    cv = jnp.einsum("bsd,lde->lbse", enc_out.astype(dt), params["dec"]["cross_v"].astype(dt))
    L = ck.shape[0]
    return ck.reshape(L, B, S, H, Dh), cv.reshape(L, B, S, H, Dh)


def _dec_embed(params: Dict, cfg: ModelConfig, tokens: jax.Array, offset: int | jax.Array = 0):
    dt = cfg.cdtype
    x = params["embed"][tokens].astype(dt)
    T = tokens.shape[1]
    if isinstance(offset, int) and offset == 0:
        pe = sinusoidal_positions(T, cfg.d_model).astype(dt)[None]
    else:
        # decode: single position
        pos = jnp.arange(T)[None, :] + offset
        pe = _sinusoid_at(pos, cfg.d_model).astype(dt)
    return x + pe


def _sinusoid_at(pos: jax.Array, d: int) -> jax.Array:
    import math as _m
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-_m.log(10000.0) / d))
    ang = pos[..., None].astype(jnp.float32) * div  # (..., d/2)
    pe = jnp.stack([jnp.sin(ang), jnp.cos(ang)], axis=-1).reshape(pos.shape + (d,))
    return pe


def forward(params: Dict, cfg: ModelConfig, frames: jax.Array, tokens: jax.Array
            ) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced training forward.  Returns (logits, aux=0)."""
    enc_out = encode(params, cfg, frames)
    ck_all, cv_all = _project_cross_kv(params, cfg, enc_out)
    x = _dec_embed(params, cfg, tokens)
    positions = jnp.arange(tokens.shape[1])

    def block(carry, xs):
        xc = carry
        bp, ck, cv = xs
        h = rms_norm(xc, bp["ln1"])
        xc = xc + attention_forward(bp["self_attn"], cfg, h, positions, causal=True)
        h2 = rms_norm(xc, bp["ln2"])
        xc = xc + _cross_attn(bp, cfg, h2, ck, cv)
        h3 = rms_norm(xc, bp["ln3"])
        xc = xc + mlp_forward(bp["mlp"], cfg, h3)
        return xc, None

    body = jax.checkpoint(block, prevent_cse=False) if cfg.remat == "block" else block
    x, _ = jax.lax.scan(body, x, (params["dec"], ck_all, cv_all),
                        unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"])
    logits = x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    return logits, jnp.zeros((), jnp.float32)


def init_state(cfg: ModelConfig, batch: int, max_len: int) -> EncDecState:
    kv = init_kv_cache(cfg, cfg.n_layers, batch, max_len)
    H, Dh = cfg.n_heads, cfg.hd
    dt = cfg.cdtype
    shape = (cfg.n_layers, batch, cfg.enc_seq, H, Dh)
    return EncDecState(
        pos=jnp.zeros((), jnp.int32),
        self_kv=kv,
        cross_k=jnp.zeros(shape, dt),
        cross_v=jnp.zeros(shape, dt),
    )


def prefill(params: Dict, cfg: ModelConfig, frames: jax.Array, tokens: jax.Array,
            state: EncDecState) -> Tuple[jax.Array, EncDecState]:
    enc_out = encode(params, cfg, frames)
    ck_all, cv_all = _project_cross_kv(params, cfg, enc_out)
    x = _dec_embed(params, cfg, tokens)
    positions = jnp.arange(tokens.shape[1])

    def block(carry, xs):
        xc = carry
        bp, ck, cv, sk, sv = xs
        h = rms_norm(xc, bp["ln1"])
        a, sk, sv = attention_prefill(bp["self_attn"], cfg, h, positions, sk, sv)
        xc = xc + a
        h2 = rms_norm(xc, bp["ln2"])
        xc = xc + _cross_attn(bp, cfg, h2, ck, cv)
        h3 = rms_norm(xc, bp["ln3"])
        xc = xc + mlp_forward(bp["mlp"], cfg, h3)
        return xc, (sk, sv)

    x, (sk_all, sv_all) = jax.lax.scan(
        block, x, (params["dec"], ck_all, cv_all, state.self_kv.k, state.self_kv.v),
        unroll=cfg.scan_unroll)
    x = rms_norm(x[:, -1:], params["final_norm"])
    logits = x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    T = tokens.shape[1]
    return logits, state._replace(
        pos=jnp.asarray(T, jnp.int32),
        self_kv=KVCache(sk_all, sv_all), cross_k=ck_all, cross_v=cv_all)


def decode_step(params: Dict, cfg: ModelConfig, token: jax.Array, state: EncDecState
                ) -> Tuple[jax.Array, EncDecState]:
    x = _dec_embed(params, cfg, token, offset=state.pos)
    pos = state.pos

    def block(carry, xs):
        xc = carry
        bp, ck, cv, sk, sv = xs
        h = rms_norm(xc, bp["ln1"])
        a, sk, sv = attention_decode(bp["self_attn"], cfg, h, pos, sk, sv)
        xc = xc + a
        h2 = rms_norm(xc, bp["ln2"])
        xc = xc + _cross_attn(bp, cfg, h2, ck, cv)
        h3 = rms_norm(xc, bp["ln3"])
        xc = xc + mlp_forward(bp["mlp"], cfg, h3)
        return xc, (sk, sv)

    x, (sk_all, sv_all) = jax.lax.scan(
        block, x, (params["dec"], state.cross_k, state.cross_v,
                   state.self_kv.k, state.self_kv.v),
        unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"])
    logits = x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    return logits, state._replace(pos=pos + 1, self_kv=KVCache(sk_all, sv_all))
