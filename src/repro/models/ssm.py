"""Recurrent sequence mixers: mLSTM / sLSTM (xLSTM) and Mamba-style S6.

All three are implemented as exact recurrences (lax.scan over time) with a
single-step form reused by the decoder loop -- O(1) state per token, which
is why the ssm/hybrid archs run the long_500k decode cell that quadratic
attention cannot.  The chunkwise-parallel mLSTM (MXU-friendly training
form) is a recorded beyond-paper optimization lever in EXPERIMENTS.md.

Shapes follow the xLSTM paper (arXiv:2405.04517) with the stabilized
exponential gating (m-state), and Mamba (arXiv:2312.00752) selective SSM
without the depthwise conv prelude (noted in DESIGN.md).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import KeyGen, dense_init, ones_init, rms_norm, zeros_init


# =============================== mLSTM =======================================

class MLSTMState(NamedTuple):
    C: jax.Array  # (B, H, Dh, Dh) matrix memory
    n: jax.Array  # (B, H, Dh)
    m: jax.Array  # (B, H)


def init_mlstm(kg: KeyGen, cfg: ModelConfig, layers: int) -> Dict:
    d, H = cfg.d_model, cfg.n_heads
    Dh = d // H
    return {
        "wq": dense_init(kg, (layers, d, H * Dh), ("layers", "embed", "heads_x_dim"), fan_in=d),
        "wk": dense_init(kg, (layers, d, H * Dh), ("layers", "embed", "heads_x_dim"), fan_in=d),
        "wv": dense_init(kg, (layers, d, H * Dh), ("layers", "embed", "heads_x_dim"), fan_in=d),
        "wi": dense_init(kg, (layers, d, H), ("layers", "embed", "heads"), fan_in=d),
        "wf": dense_init(kg, (layers, d, H), ("layers", "embed", "heads"), fan_in=d),
        "bf": ones_init((layers, H), ("layers", "heads")),  # forget bias > 0 helps
        "wog": dense_init(kg, (layers, d, H * Dh), ("layers", "embed", "heads_x_dim"), fan_in=d),
        "wo": dense_init(kg, (layers, H * Dh, d), ("layers", "heads_x_dim", "embed"), fan_in=H * Dh),
        "gn": zeros_init((layers, H * Dh), ("layers", None)),  # per-head group norm scale
    }


def mlstm_init_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    H = cfg.n_heads
    Dh = cfg.d_model // H
    f32 = jnp.float32
    return MLSTMState(
        C=jnp.zeros((batch, H, Dh, Dh), f32),
        n=jnp.zeros((batch, H, Dh), f32),
        m=jnp.full((batch, H), -1e30, f32),
    )


def _mlstm_cell(
    state: MLSTMState,
    q: jax.Array, k: jax.Array, v: jax.Array,  # (B, H, Dh)
    it: jax.Array, ft: jax.Array,              # (B, H) pre-activations
) -> Tuple[MLSTMState, jax.Array]:
    Dh = q.shape[-1]
    m_new = jnp.maximum(ft + state.m, it)                       # (B, H)
    i_g = jnp.exp(it - m_new)
    f_g = jnp.exp(ft + state.m - m_new)
    C = f_g[..., None, None] * state.C + i_g[..., None, None] * (
        v[..., :, None] * k[..., None, :]
    )  # (B, H, Dh, Dh) = f*C + i * v k^T
    n = f_g[..., None] * state.n + i_g[..., None] * k
    h_num = jnp.einsum("bhvk,bhk->bhv", C, q)                   # C q
    h_den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, q))
    h = h_num / jnp.maximum(h_den, 1.0)[..., None]
    return MLSTMState(C, n, m_new), h


def mlstm_forward(
    p: Dict, cfg: ModelConfig, x: jax.Array, state: MLSTMState
) -> Tuple[jax.Array, MLSTMState]:
    """Train/prefill form: scan over time.  x: (B, T, d) -> (B, T, d)."""
    B, T, d = x.shape
    H = cfg.n_heads
    Dh = d // H
    dt = cfg.cdtype
    scale = Dh**-0.5
    q = (x @ p["wq"].astype(dt)).reshape(B, T, H, Dh).astype(jnp.float32) * scale
    k = (x @ p["wk"].astype(dt)).reshape(B, T, H, Dh).astype(jnp.float32) * scale
    v = (x @ p["wv"].astype(dt)).reshape(B, T, H, Dh).astype(jnp.float32)
    it = (x @ p["wi"].astype(dt)).astype(jnp.float32)           # (B, T, H)
    ft = (x @ p["wf"].astype(dt)).astype(jnp.float32) + p["bf"].astype(jnp.float32)
    og = jax.nn.sigmoid((x @ p["wog"].astype(dt)).astype(jnp.float32))

    def step(s, inp):
        qt, kt, vt, i_t, f_t = inp
        s, h = _mlstm_cell(s, qt, kt, vt, i_t, f_t)
        return s, h

    xs = (
        q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
        it.swapaxes(0, 1), ft.swapaxes(0, 1),
    )
    state, hs = jax.lax.scan(step, state, xs)                   # hs: (T, B, H, Dh)
    h = hs.swapaxes(0, 1).reshape(B, T, H * Dh)
    h = rms_norm(h, p["gn"]) * og.reshape(B, T, H * Dh)
    return (h.astype(dt) @ p["wo"].astype(dt)), state


def mlstm_decode(
    p: Dict, cfg: ModelConfig, x: jax.Array, state: MLSTMState
) -> Tuple[jax.Array, MLSTMState]:
    out, state = mlstm_forward(p, cfg, x, state)  # T=1 scan is the step
    return out, state


# =============================== sLSTM =======================================

class SLSTMState(NamedTuple):
    c: jax.Array  # (B, H, Dh)
    n: jax.Array  # (B, H, Dh)
    h: jax.Array  # (B, H, Dh)
    m: jax.Array  # (B, H, Dh)


def init_slstm(kg: KeyGen, cfg: ModelConfig, layers: int) -> Dict:
    d, H = cfg.d_model, cfg.n_heads
    Dh = d // H
    p = {}
    for g in ("z", "i", "f", "o"):
        p[f"w{g}"] = dense_init(kg, (layers, d, H * Dh), ("layers", "embed", "heads_x_dim"), fan_in=d)
        p[f"r{g}"] = dense_init(
            kg, (layers, H, Dh, Dh), ("layers", "heads", "head_dim", None), fan_in=Dh
        )  # block-diagonal recurrent weights (per head)
    p["bf"] = ones_init((layers, H * Dh), ("layers", None))
    p["gn"] = zeros_init((layers, H * Dh), ("layers", None))
    p["wo"] = dense_init(kg, (layers, H * Dh, d), ("layers", "heads_x_dim", "embed"), fan_in=H * Dh)
    return p


def slstm_init_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    H = cfg.n_heads
    Dh = cfg.d_model // H
    z = jnp.zeros((batch, H, Dh), jnp.float32)
    return SLSTMState(z, z, z, jnp.full_like(z, -1e30))


def slstm_forward(
    p: Dict, cfg: ModelConfig, x: jax.Array, state: SLSTMState
) -> Tuple[jax.Array, SLSTMState]:
    B, T, d = x.shape
    H = cfg.n_heads
    Dh = d // H
    dt = cfg.cdtype
    f32 = jnp.float32
    pre = {
        g: (x @ p[f"w{g}"].astype(dt)).reshape(B, T, H, Dh).astype(f32)
        for g in ("z", "i", "f", "o")
    }
    pre["f"] = pre["f"] + p["bf"].astype(f32).reshape(1, 1, H, Dh)
    R = {g: p[f"r{g}"].astype(f32) for g in ("z", "i", "f", "o")}

    def step(s, inp):
        zx, ix, fx, ox = inp  # (B, H, Dh) each

        def rec(g, hprev):
            return jnp.einsum("bhk,hkd->bhd", hprev, R[g])

        zt = jnp.tanh(zx + rec("z", s.h))
        it = ix + rec("i", s.h)
        ft = fx + rec("f", s.h)
        ot = jax.nn.sigmoid(ox + rec("o", s.h))
        m_new = jnp.maximum(ft + s.m, it)
        i_g = jnp.exp(it - m_new)
        f_g = jnp.exp(ft + s.m - m_new)
        c = f_g * s.c + i_g * zt
        n = f_g * s.n + i_g
        h = ot * c / jnp.maximum(n, 1.0)
        return SLSTMState(c, n, h, m_new), h

    xs = tuple(pre[g].swapaxes(0, 1) for g in ("z", "i", "f", "o"))
    state, hs = jax.lax.scan(step, state, xs)
    h = hs.swapaxes(0, 1).reshape(B, T, H * Dh)
    h = rms_norm(h, p["gn"])
    return (h.astype(dt) @ p["wo"].astype(dt)), state


# =============================== Mamba (S6) ====================================

class MambaState(NamedTuple):
    S: jax.Array  # (B, d_inner, N)


def init_mamba(kg: KeyGen, cfg: ModelConfig, layers: int) -> Dict:
    d = cfg.d_model
    N = cfg.ssm_state
    return {
        "w_in": dense_init(kg, (layers, d, d), ("layers", "embed", "ffn_inner"), fan_in=d),
        "w_delta": dense_init(kg, (layers, d, d), ("layers", "embed", "ffn_inner"), fan_in=d),
        "b_delta": zeros_init((layers, d), ("layers", None)),
        "w_B": dense_init(kg, (layers, d, N), ("layers", "embed", None), fan_in=d),
        "w_C": dense_init(kg, (layers, d, N), ("layers", "embed", None), fan_in=d),
        "A_log": zeros_init((layers, d, N), ("layers", "ffn_inner", None)),
        "D": ones_init((layers, d), ("layers", None)),
        "w_out": dense_init(kg, (layers, d, d), ("layers", "ffn_inner", "embed"), fan_in=d),
    }


def mamba_init_state(cfg: ModelConfig, batch: int) -> MambaState:
    return MambaState(jnp.zeros((batch, cfg.d_model, cfg.ssm_state), jnp.float32))


def mamba_forward(
    p: Dict, cfg: ModelConfig, x: jax.Array, state: MambaState
) -> Tuple[jax.Array, MambaState]:
    B, T, d = x.shape
    N = cfg.ssm_state
    dt = cfg.cdtype
    f32 = jnp.float32
    u = jax.nn.silu(x @ p["w_in"].astype(dt)).astype(f32)               # (B, T, d)
    delta = jax.nn.softplus(
        (x @ p["w_delta"].astype(dt)).astype(f32) + p["b_delta"].astype(f32)
    )                                                                    # (B, T, d)
    Bm = (x @ p["w_B"].astype(dt)).astype(f32)                           # (B, T, N)
    Cm = (x @ p["w_C"].astype(dt)).astype(f32)                           # (B, T, N)
    A = -jnp.exp(p["A_log"].astype(f32))                                 # (d, N)

    def step(S, inp):
        ut, dt_, bt, ct = inp
        decay = jnp.exp(dt_[..., None] * A[None])                        # (B, d, N)
        S = S * decay + (dt_ * ut)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", S, ct)
        return S, y

    xs = (u.swapaxes(0, 1), delta.swapaxes(0, 1), Bm.swapaxes(0, 1), Cm.swapaxes(0, 1))
    S, ys = jax.lax.scan(step, state.S, xs)
    y = ys.swapaxes(0, 1) + p["D"].astype(f32) * u                       # (B, T, d)
    return (y.astype(dt) @ p["w_out"].astype(dt)), MambaState(S)
