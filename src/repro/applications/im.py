"""Dynamic Influence Maximization on evolving graphs (paper Sec 5).

Weighted-Cascade RR-set machinery where step (ii) of RR-set generation --
"sample the incoming neighbours of a visited vertex" -- is exactly a
Poisson pi-ps query over the in-edge weights (c = 1).  Each vertex carries
its own dynamic index; edge insertions/deletions touch one vertex's index:

  * DIPS backend:      O(1) per edge update (paper's contribution)
  * R-ODSS/brute:      O(in-degree) rebuild per update (SS reduction)

``greedy_seed_selection`` is the standard max-coverage greedy over sampled
RR sets (SUBSIM-style evaluation harness, scaled to container size).
"""

from __future__ import annotations

import time
from typing import Dict, Hashable, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..core import DIPS, BruteForcePPS, R_ODSS

BACKENDS = {"DIPS": DIPS, "R-ODSS": R_ODSS, "BruteForce": BruteForcePPS}


class DynamicWCGraph:
    """Directed graph under the Weighted Cascade model with per-vertex
    dynamic PPS indexes over in-neighbour weights."""

    def __init__(self, n: int, backend: str = "DIPS", seed: int = 0) -> None:
        self.n = n
        self.backend = backend
        self._ctor = BACKENDS[backend]
        self._seed = seed
        self.in_index: Dict[int, object] = {}
        self.rng = np.random.default_rng(seed)

    @classmethod
    def from_edges(cls, n: int, edges: Sequence[Tuple[int, int, float]],
                   backend: str = "DIPS", seed: int = 0) -> "DynamicWCGraph":
        g = cls(n, backend, seed)
        by_target: Dict[int, Dict[int, float]] = {}
        for u, v, w in edges:
            by_target.setdefault(v, {})[u] = w
        for v, nbrs in by_target.items():
            g.in_index[v] = g._ctor(nbrs, c=1.0, seed=seed + v)
        return g

    # -- dynamic edge operations --------------------------------------------
    def insert_edge(self, u: int, v: int, w: float) -> None:
        idx = self.in_index.get(v)
        if idx is None:
            idx = self.in_index[v] = self._ctor({u: w}, c=1.0, seed=self._seed + v)
        else:
            idx.insert(u, w)

    def delete_edge(self, u: int, v: int) -> None:
        self.in_index[v].delete(u)

    def change_edge_weight(self, u: int, v: int, w: float) -> None:
        self.in_index[v].change_w(u, w)

    # -- RR sets -----------------------------------------------------------------
    def rr_set(self, target: Optional[int] = None) -> Set[int]:
        """Reverse-reachable set via stochastic reverse BFS; each visited
        vertex samples its in-neighbours with one PPS query."""
        if target is None:
            target = int(self.rng.integers(self.n))
        visited = {target}
        frontier = [target]
        while frontier:
            nxt = []
            for v in frontier:
                idx = self.in_index.get(v)
                if idx is None:
                    continue
                for u in idx.query(self.rng):
                    if u not in visited:
                        visited.add(u)
                        nxt.append(u)
            frontier = nxt
        return visited


def greedy_seed_selection(rr_sets: List[Set[int]], k: int) -> Tuple[List[int], float]:
    """Max-coverage greedy; returns (seeds, covered fraction)."""
    covering: Dict[int, List[int]] = {}
    for i, rr in enumerate(rr_sets):
        for v in rr:
            covering.setdefault(v, []).append(i)
    covered = np.zeros(len(rr_sets), bool)
    seeds: List[int] = []
    for _ in range(k):
        best_v, best_gain = -1, -1
        for v, lst in covering.items():
            gain = sum(1 for i in lst if not covered[i])
            if gain > best_gain:
                best_v, best_gain = v, gain
        if best_v < 0 or best_gain <= 0:
            break
        seeds.append(best_v)
        for i in covering.pop(best_v, []):
            covered[i] = True
    return seeds, float(covered.mean()) if len(rr_sets) else 0.0


def influence_maximization(
    graph: DynamicWCGraph, k: int, n_rr: int
) -> Tuple[List[int], float, float]:
    """Sample n_rr RR sets then pick k seeds.  Returns (seeds, coverage, secs)."""
    t0 = time.perf_counter()
    rr_sets = [graph.rr_set() for _ in range(n_rr)]
    seeds, cov = greedy_seed_selection(rr_sets, k)
    return seeds, cov, time.perf_counter() - t0


# ------------------------------ synthetic graphs --------------------------------

def synthetic_powerlaw_edges(
    n: int, m_per_node: int = 4, weight_dist: str = "exponential",
    seed: int = 0,
) -> List[Tuple[int, int, float]]:
    """Preferential-attachment digraph with exponential or Weibull weights
    (paper Sec 5 distributions; Weibull a,b ~ U[0,10] per edge)."""
    rng = np.random.default_rng(seed)
    targets = list(range(m_per_node))
    edges: List[Tuple[int, int, float]] = []
    repeated: List[int] = list(range(m_per_node))
    for v in range(m_per_node, n):
        chosen = set()
        for t in targets[:m_per_node]:
            chosen.add(t)
        # preferential attachment by sampling the repeated-node list
        while len(chosen) < m_per_node:
            chosen.add(int(repeated[rng.integers(len(repeated))]))
        for u in chosen:
            if weight_dist == "exponential":
                w = float(rng.exponential(1.0)) + 1e-12
            else:  # weibull
                a = rng.uniform(0, 10) + 1e-3
                b = rng.uniform(0, 10) + 1e-3
                w = float(a * rng.weibull(b)) + 1e-12
            edges.append((u, v, w))
            repeated.extend((u, v))
    return edges
