"""Dynamic Influence Maximization on evolving graphs (paper Sec 5).

Weighted-Cascade RR-set machinery where step (ii) of RR-set generation --
"sample the incoming neighbours of a visited vertex" -- is exactly a
Poisson pi-ps query over the in-edge weights (c = 1).  Each vertex carries
its own dynamic sampler built through the ``repro.engine`` registry, so
any backend plugs in by name:

  * host-dips:          O(1) per edge update (paper's contribution)
  * host-rodss/brute:   O(in-degree) rebuild per update (SS reduction)
  * jax-* / pallas-*:   device engines; ``rr_sets`` groups the frontier by
    vertex and expands all RR sets visiting the same vertex with ONE
    ``query_batch`` call (batched RR-set expansion on device).

``greedy_seed_selection`` is the standard max-coverage greedy over sampled
RR sets (SUBSIM-style evaluation harness, scaled to container size).
"""

from __future__ import annotations

import time
from typing import Dict, Hashable, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..engine import SamplerEngine, engine_kind, make_engine


class DynamicWCGraph:
    """Directed graph under the Weighted Cascade model with per-vertex
    dynamic PPS samplers over in-neighbour weights.

    ``backend`` is any name in the engine registry (legacy method names
    such as "DIPS" and "R-ODSS" resolve as aliases).
    """

    def __init__(self, n: int, backend: str = "host-dips", seed: int = 0,
                 **engine_opts) -> None:
        self.n = n
        self.backend = backend
        self.backend_kind = engine_kind(backend)
        self._engine_opts = engine_opts
        self._seed = seed
        self.in_index: Dict[int, SamplerEngine] = {}
        self.rng = np.random.default_rng(seed)

    def _make(self, items: Dict[int, float], v: int) -> SamplerEngine:
        return make_engine(self.backend, items, c=1.0, seed=self._seed + v,
                           **self._engine_opts)

    @classmethod
    def from_edges(cls, n: int, edges: Sequence[Tuple[int, int, float]],
                   backend: str = "host-dips", seed: int = 0,
                   **engine_opts) -> "DynamicWCGraph":
        g = cls(n, backend, seed, **engine_opts)
        by_target: Dict[int, Dict[int, float]] = {}
        for u, v, w in edges:
            by_target.setdefault(v, {})[u] = w
        for v, nbrs in by_target.items():
            g.in_index[v] = g._make(nbrs, v)
        return g

    # -- dynamic edge operations --------------------------------------------
    def insert_edge(self, u: int, v: int, w: float) -> None:
        idx = self.in_index.get(v)
        if idx is None:
            self.in_index[v] = self._make({u: w}, v)
        else:
            idx.insert(u, w)

    def delete_edge(self, u: int, v: int) -> None:
        self.in_index[v].delete(u)

    def change_edge_weight(self, u: int, v: int, w: float) -> None:
        self.in_index[v].change_w(u, w)

    # -- RR sets -----------------------------------------------------------------
    def rr_set(self, target: Optional[int] = None) -> Set[int]:
        """Reverse-reachable set via stochastic reverse BFS; each visited
        vertex samples its in-neighbours with one PPS query."""
        if target is None:
            target = int(self.rng.integers(self.n))
        visited = {target}
        frontier = [target]
        while frontier:
            nxt = []
            for v in frontier:
                idx = self.in_index.get(v)
                if idx is None:
                    continue
                for u in idx.query(self.rng):
                    if u not in visited:
                        visited.add(u)
                        nxt.append(u)
            frontier = nxt
        return visited

    def rr_sets(self, count: int) -> List[Set[int]]:
        """``count`` RR sets, expanded level-synchronously.

        Per BFS round the frontier is grouped by vertex, and each vertex's
        engine answers all RR sets that reached it with one ``query_batch``
        -- on device engines that is a single fused program per (vertex,
        round) instead of one dispatch per (RR set, vertex) visit.
        """
        import jax

        targets = [int(t) for t in self.rng.integers(self.n, size=count)]
        visited: List[Set[int]] = [{t} for t in targets]
        frontier: List[List[int]] = [[t] for t in targets]
        while True:
            by_vertex: Dict[int, List[int]] = {}
            for rr_id, verts in enumerate(frontier):
                for v in verts:
                    if v in self.in_index:
                        by_vertex.setdefault(v, []).append(rr_id)
            if not by_vertex:
                break
            nxt: List[List[int]] = [[] for _ in range(count)]
            for v, rr_ids in by_vertex.items():
                eng = self.in_index[v]
                if len(rr_ids) == 1:
                    samples = [eng.query(self.rng)]
                else:
                    key = jax.random.key(int(self.rng.integers(2**63 - 1)))
                    # round the batch up to a power of two so frontier-size
                    # jitter reuses a handful of compiled programs instead
                    # of recompiling per distinct group size
                    b = 1 << (len(rr_ids) - 1).bit_length()
                    ids, cnts = eng.query_batch(key, b)
                    samples = eng.decode_batch(
                        ids[: len(rr_ids)], cnts[: len(rr_ids)])
                for rr_id, sample in zip(rr_ids, samples):
                    for u in sample:
                        if u not in visited[rr_id]:
                            visited[rr_id].add(u)
                            nxt[rr_id].append(u)
            frontier = nxt
        return visited


def greedy_seed_selection(rr_sets: List[Set[int]], k: int) -> Tuple[List[int], float]:
    """Max-coverage greedy; returns (seeds, covered fraction)."""
    covering: Dict[int, List[int]] = {}
    for i, rr in enumerate(rr_sets):
        for v in rr:
            covering.setdefault(v, []).append(i)
    covered = np.zeros(len(rr_sets), bool)
    seeds: List[int] = []
    for _ in range(k):
        best_v, best_gain = -1, -1
        for v, lst in covering.items():
            gain = sum(1 for i in lst if not covered[i])
            if gain > best_gain:
                best_v, best_gain = v, gain
        if best_v < 0 or best_gain <= 0:
            break
        seeds.append(best_v)
        for i in covering.pop(best_v, []):
            covered[i] = True
    return seeds, float(covered.mean()) if len(rr_sets) else 0.0


def influence_maximization(
    graph: DynamicWCGraph, k: int, n_rr: int
) -> Tuple[List[int], float, float]:
    """Sample n_rr RR sets then pick k seeds.  Returns (seeds, coverage, secs).

    Device backends use the grouped/batched expansion; host backends keep
    the one-query-at-a-time path (identical distribution, no batching win).
    """
    t0 = time.perf_counter()
    if graph.backend_kind == "device":
        rr_sets = graph.rr_sets(n_rr)
    else:
        rr_sets = [graph.rr_set() for _ in range(n_rr)]
    seeds, cov = greedy_seed_selection(rr_sets, k)
    return seeds, cov, time.perf_counter() - t0


# ------------------------------ synthetic graphs --------------------------------

def synthetic_powerlaw_edges(
    n: int, m_per_node: int = 4, weight_dist: str = "exponential",
    seed: int = 0,
) -> List[Tuple[int, int, float]]:
    """Preferential-attachment digraph with exponential or Weibull weights
    (paper Sec 5 distributions; Weibull a,b ~ U[0,10] per edge)."""
    rng = np.random.default_rng(seed)
    targets = list(range(m_per_node))
    edges: List[Tuple[int, int, float]] = []
    repeated: List[int] = list(range(m_per_node))
    for v in range(m_per_node, n):
        chosen = set()
        for t in targets[:m_per_node]:
            chosen.add(t)
        # preferential attachment by sampling the repeated-node list
        while len(chosen) < m_per_node:
            chosen.add(int(repeated[rng.integers(len(repeated))]))
        for u in chosen:
            if weight_dist == "exponential":
                w = float(rng.exponential(1.0)) + 1e-12
            else:  # weibull
                a = rng.uniform(0, 10) + 1e-3
                b = rng.uniform(0, 10) + 1e-3
                w = float(a * rng.weibull(b)) + 1e-12
            edges.append((u, v, w))
            repeated.extend((u, v))
    return edges
