"""Dry-run profiling helper: dump the largest collectives / ops of a cell's
cost probe (the hillclimb 'profiler' -- no hardware, so the lowered IR and
cost analysis ARE the profile)."""

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")).strip()

import argparse
import collections

import jax

from repro.configs import SHAPES, get_config
from repro.launch.dryrun import _build_lowered, _probe_cfg
from repro.launch.hlo_analysis import _DTYPE_BYTES, _SHAPE_RE, _GROUPS_RE, _IOTA_GROUPS_RE
from repro.launch.mesh import make_production_mesh
from repro.sharding.context import activation_mesh


def nbytes(dtype, dims):
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--top", type=int, default=14)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--kind", default="coll", choices=["coll", "ops"])
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi)
    cfg = _probe_cfg(get_config(args.arch), args.layers)
    with activation_mesh(mesh):
        lowered, _ = _build_lowered(cfg, SHAPES[args.shape], mesh)
        compiled = lowered.compile()
    text = compiled.as_text()

    rows = []
    agg = collections.Counter()
    for line in text.splitlines():
        ls = line.strip()
        if "= " not in ls or ls.startswith("//"):
            continue
        rhs = ls.split("= ", 1)[1]
        head = rhs.split("(")[0].strip().split()
        if not head:
            continue
        opname = head[-1]
        if args.kind == "coll" and not any(
                c in opname for c in ("all-reduce", "all-gather", "reduce-scatter",
                                      "all-to-all", "collective-permute")):
            continue
        m = _SHAPE_RE.findall(rhs.split("(")[0])
        if not m:
            continue
        b = sum(nbytes(d, dd) for d, dd in m)
        rows.append((b, opname, m[:2], ls[:110]))
        agg[opname] += b
    rows.sort(reverse=True)
    for b, op, shapes, _ in rows[: args.top]:
        print(f"{b/1e9:9.3f} GB  {op:22s} {shapes}")
    print("\n-- aggregate by op --")
    for op, b in agg.most_common(12):
        print(f"{b/1e9:9.2f} GB  {op}")
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, list) else cost
    print(f"\nflops={cost.get('flops'):.3e} bytes={cost.get('bytes accessed'):.3e}")


if __name__ == "__main__":
    main()
