"""Framework-integration benchmark: per-example weight updates in the data
pipeline, DIPS vs the SS-reduction alternative.

Every training step updates B example weights; with DIPS each is O(1),
while a subset-sampling pipeline recomputes all pool probabilities.  This
measures exactly the gap that motivates using DIPS inside the trainer.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.engine import make_engine

from .common import csv_row


def bench_pipeline_updates(pools=(1_000, 10_000, 100_000), batch: int = 64,
                           steps: int = 20, seed: int = 0,
                           engines=("host-dips", "host-rodss", "jax-bucketed")
                           ) -> List[dict]:
    rows = []
    rng = np.random.default_rng(seed)
    for pool in pools:
        for name in engines:
            items = {i: 1.0 for i in range(pool)}
            idx = make_engine(name, items, c=1.0, seed=seed)
            n_steps = max(2, steps // 10) if idx.UPDATE_REBUILDS else steps
            if idx.NATIVE_BATCH:
                import jax

                idx.query_batch(jax.random.key(99991), 1)  # compile outside timing
            t0 = time.perf_counter()
            for s in range(n_steps):
                ids = rng.integers(0, pool, batch)
                losses = rng.random(batch) * 10
                for i, l in zip(ids, losses):
                    idx.change_w(int(i), float(l) + 1e-3)
                if idx.NATIVE_BATCH:
                    # a real pipeline samples every step; this charges the
                    # deferred delta-buffer flush to the updates it serves
                    idx.query_batch(jax.random.key(s), 1)
            per_update = (time.perf_counter() - t0) / (n_steps * batch)
            rows.append({"fig": "pipeline", "method": name, "pool": pool,
                         "update_us": per_update * 1e6})
            print(csv_row(f"pipeline/{name}/pool{pool}", per_update * 1e6))
    return rows
