"""Framework-integration benchmark: per-example weight updates in the data
pipeline, DIPS vs the SS-reduction alternative.

Every training step updates B example weights; with DIPS each is O(1),
while a subset-sampling pipeline recomputes all pool probabilities.  This
measures exactly the gap that motivates using DIPS inside the trainer.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import DIPS, R_ODSS

from .common import csv_row


def bench_pipeline_updates(pools=(1_000, 10_000, 100_000), batch: int = 64,
                           steps: int = 20, seed: int = 0) -> List[dict]:
    rows = []
    rng = np.random.default_rng(seed)
    for pool in pools:
        for name, ctor in (("DIPS", DIPS), ("R-ODSS", R_ODSS)):
            items = {i: 1.0 for i in range(pool)}
            idx = ctor(items, c=1.0, seed=seed)
            n_steps = steps if name == "DIPS" else max(2, steps // 10)
            t0 = time.perf_counter()
            for s in range(n_steps):
                ids = rng.integers(0, pool, batch)
                losses = rng.random(batch) * 10
                for i, l in zip(ids, losses):
                    idx.change_w(int(i), float(l) + 1e-3)
            per_update = (time.perf_counter() - t0) / (n_steps * batch)
            rows.append({"fig": "pipeline", "method": name, "pool": pool,
                         "update_us": per_update * 1e6})
            print(csv_row(f"pipeline/{name}/pool{pool}", per_update * 1e6))
    return rows
