"""Roofline table generator: reads the dry-run JSON, emits the EXPERIMENTS
section tables (per arch x shape x mesh: three terms, dominant bottleneck,
model-vs-HLO FLOP ratio, memory feasibility)."""

from __future__ import annotations

import json
from pathlib import Path
from typing import List, Optional

HBM_LIMIT = 16e9  # v5e per-chip HBM


def load(path: str = "benchmarks/results/dryrun.json") -> List[dict]:
    return json.loads(Path(path).read_text())


def fmt_s(x: float) -> str:
    return f"{x:.3e}"


def table(records: List[dict], mesh: Optional[str] = "16x16") -> str:
    rows = []
    head = ("| arch | shape | mesh | compute s | memory s | collective s | "
            "dominant | useful/HLO | fit<16G |")
    sep = "|" + "---|" * 9
    rows.append(head)
    rows.append(sep)
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if mesh and r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"skip | skip | skip | n/a | n/a | n/a |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR | | | | | |")
            continue
        t = r["roofline"]
        ma = r.get("memory_analysis", {})
        resident = (r.get("state_bytes_per_device", 0)
                    + r.get("params_bytes_per_device", 0))
        temp = ma.get("temp_size_in_bytes", 0)
        fits = "yes" if (resident + temp) < HBM_LIMIT else (
            f"no ({(resident+temp)/1e9:.0f}G)")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} | "
            f"{fmt_s(t['collective_s'])} | {t['dominant'].replace('_s','')} | "
            f"{r['useful_flops_ratio']:.2f} | {fits} |")
    return "\n".join(rows)


def summary(records: List[dict]) -> str:
    ok = [r for r in records if r["status"] == "ok"]
    lines = []
    for dom in ("compute_s", "memory_s", "collective_s"):
        cells = [r for r in ok if r["roofline"]["dominant"] == dom]
        lines.append(f"{dom}: dominant in {len(cells)} cells")
    worst = sorted(
        (r for r in ok if r["shape"] == "train_4k"),
        key=lambda r: r["roofline"]["roofline_fraction_compute"])[:5]
    lines.append("worst train-compute fractions: " + ", ".join(
        f"{r['arch']}@{r['mesh']}="
        f"{r['roofline']['roofline_fraction_compute']:.3f}" for r in worst))
    return "\n".join(lines)


def main() -> None:
    records = load()
    for mesh in ("16x16", "2x16x16"):
        print(f"\n## Roofline ({mesh})\n")
        print(table(records, mesh))
    print("\n## Summary\n")
    print(summary(records))
    out = Path("benchmarks/results/roofline.md")
    with out.open("w") as f:
        for mesh in ("16x16", "2x16x16"):
            f.write(f"\n### Mesh {mesh}\n\n")
            f.write(table(records, mesh))
            f.write("\n")
        f.write("\n### Summary\n\n" + summary(records) + "\n")
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
