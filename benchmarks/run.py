"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--quick`` (default) uses
container-scale sizes; ``--full`` approaches paper-scale n (hours).
Results are also dumped as json (``--out``, default
benchmarks/results/bench_results.json -- the committed copy of that file
is the CI perf-gate baseline, see benchmarks/check_regression.py) for
the EXPERIMENTS.md tables.

  fig1    max-abs-error vs repeats (correctness, paper Fig 1)
  fig2    query/update tradeoff (paper Fig 2)
  fig3    query time vs n, c in {1.0, 0.4} (paper Figs 3, 7-9)
  fig4    update time vs n (paper Fig 4)
  table1  memory usage DIPS vs R-ODSS (paper Table 1)
  fig5/6  dynamic influence maximization (paper Sec 5)
  pipeline  DIPS-vs-rebuild data-pipeline weight updates (framework)
  churn   device-engine recompiles + sample latency under steady churn
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="container-scale sizes (the default; explicit flag "
                         "for CI invocations)")
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--only", default="", help="comma list: fig1,fig2,...")
    ap.add_argument("--out", default="benchmarks/results/bench_results.json",
                    help="output json path (CI writes elsewhere and diffs "
                         "against the committed history)")
    args = ap.parse_args()
    if args.quick and args.full:
        ap.error("--quick and --full are mutually exclusive")

    from . import bench_im, bench_paper
    from .bench_pipeline import bench_pipeline_updates

    full = args.full
    only = set(args.only.split(",")) if args.only else None

    def want(name: str) -> bool:
        return only is None or name in only

    all_rows = []
    t0 = time.time()
    if want("fig1"):
        all_rows += bench_paper.bench_correctness(
            n=100_000 if full else 10_000,
            repeat_grid=(1_000, 10_000, 100_000, 1_000_000) if full
            else (1_000, 10_000, 100_000))
    if want("fig2"):
        all_rows += bench_paper.bench_tradeoff(n=100_000 if full else 50_000)
    if want("fig3"):
        all_rows += bench_paper.bench_query(
            ns=(10_000, 100_000, 1_000_000, 10_000_000) if full
            else (10_000, 100_000, 1_000_000))
    if want("fig4"):
        all_rows += bench_paper.bench_update(
            ns=(10_000, 100_000, 1_000_000, 10_000_000) if full
            else (10_000, 100_000, 1_000_000))
    if want("table1"):
        all_rows += bench_paper.bench_memory(
            ns=(10_000, 100_000, 1_000_000))
    if want("fig5"):
        all_rows += bench_im.bench_im_runtime(
            n_nodes=100_000 if full else 20_000,
            n_rr=5000 if full else 1500)
    if want("fig6"):
        all_rows += bench_im.bench_im_updates(
            n_nodes=100_000 if full else 20_000)
    if want("pipeline"):
        all_rows += bench_pipeline_updates(
            pools=(1_000, 10_000, 100_000) if not full
            else (10_000, 100_000, 1_000_000))
    if want("churn"):
        all_rows += bench_paper.bench_churn(
            n=100_000 if full else 20_000,
            rounds=100 if full else 30)

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(all_rows, indent=1))
    print(f"# wrote {len(all_rows)} records to {out} "
          f"in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
