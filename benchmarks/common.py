"""Shared benchmark machinery: distributions, timing, method registry."""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

from repro.core import DIPS, BruteForcePPS, R_BSS, R_HSS, R_ODSS

#: paper Sec 4.1 weight distributions (parameters as published; the plain
#: normal is folded at zero to yield valid weights -- noted in DESIGN.md)
DISTRIBUTIONS: Dict[str, Callable[[np.random.Generator, int], np.ndarray]] = {
    "exponential": lambda r, n: r.exponential(1.0, n),
    "normal": lambda r, n: np.abs(r.normal(0.0, np.sqrt(10.0), n)) + 1e-12,
    "half_normal": lambda r, n: np.abs(r.normal(0.0, np.sqrt(10.0), n)) + 1e-12,
    "lognormal": lambda r, n: r.lognormal(0.0, np.sqrt(np.log(2.0)), n),
}

METHODS = {
    "DIPS": lambda items, c, seed: DIPS(items, c=c, seed=seed),
    "R-ODSS": lambda items, c, seed: R_ODSS(items, c=c, seed=seed),
    "R-BSS": lambda items, c, seed: R_BSS(items, c=c, seed=seed),
    "R-HSS": lambda items, c, seed: R_HSS(items, c=c, seed=seed),
    "BruteForce": lambda items, c, seed: BruteForcePPS(items, c=c, seed=seed),
}


def make_items(dist: str, n: int, seed: int = 0) -> Dict[int, float]:
    rng = np.random.default_rng(seed)
    w = DISTRIBUTIONS[dist](rng, n)
    return {i: float(x) for i, x in enumerate(w)}


def time_queries(idx, repeats: int, rng) -> float:
    """Mean seconds per query."""
    t0 = time.perf_counter()
    for _ in range(repeats):
        idx.query(rng)
    return (time.perf_counter() - t0) / repeats


def time_updates(idx, n_base: int, ops: int, rng, weight_fn) -> float:
    """Mean seconds per update (insert+delete pairs, amortized)."""
    t0 = time.perf_counter()
    for i in range(ops):
        idx.insert(("bench", i), float(weight_fn()))
    for i in range(ops):
        idx.delete(("bench", i))
    return (time.perf_counter() - t0) / (2 * ops)


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.3f},{derived}"
