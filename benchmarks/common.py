"""Shared benchmark machinery: distributions, timing, engine enumeration.

Methods are enumerated from the ``repro.engine`` registry, so every new
backend automatically shows up in every benchmark scenario -- host and
device side by side.  ``METHODS`` keeps the historical ``ctor(items, c,
seed)`` call shape.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

from repro.engine import available_engines, make_engine

#: paper Sec 4.1 weight distributions (parameters as published; the plain
#: normal is folded at zero to yield valid weights -- noted in DESIGN.md)
DISTRIBUTIONS: Dict[str, Callable[[np.random.Generator, int], np.ndarray]] = {
    "exponential": lambda r, n: r.exponential(1.0, n),
    "normal": lambda r, n: np.abs(r.normal(0.0, np.sqrt(10.0), n)) + 1e-12,
    "half_normal": lambda r, n: np.abs(r.normal(0.0, np.sqrt(10.0), n)) + 1e-12,
    "lognormal": lambda r, n: r.lognormal(0.0, np.sqrt(np.log(2.0)), n),
}


def _ctor(name: str):
    return lambda items, c, seed: make_engine(name, items, c=c, seed=seed)


#: every registered engine, constructed through the registry; filter by
#: kind with repro.engine.available_engines(kind=...)
METHODS = {name: _ctor(name) for name in available_engines()}


def make_items(dist: str, n: int, seed: int = 0) -> Dict[int, float]:
    rng = np.random.default_rng(seed)
    w = DISTRIBUTIONS[dist](rng, n)
    return {i: float(x) for i, x in enumerate(w)}


def time_queries(idx, repeats: int, rng) -> float:
    """Mean seconds per single query (host cost model)."""
    t0 = time.perf_counter()
    for _ in range(repeats):
        idx.query(rng)
    return (time.perf_counter() - t0) / repeats


def time_queries_batched(engine, repeats: int, seed: int = 0,
                         chunk: int = 256) -> float:
    """Mean seconds per query through query_batch (device cost model).

    One warmup chunk is excluded so jit compilation does not pollute the
    steady-state number.
    """
    import jax

    engine.query_batch(jax.random.key(seed), chunk)  # warmup/compile
    done = 0
    t0 = time.perf_counter()
    while done < repeats:
        b = min(chunk, repeats - done)
        if b < chunk:
            b = chunk  # keep one compiled shape
        engine.query_batch(jax.random.key(seed + 1 + done), b)
        done += b
    return (time.perf_counter() - t0) / done


def time_engine_queries(engine, repeats: int, rng, seed: int = 0) -> float:
    """Dispatch to the engine's natural query cost model."""
    if getattr(engine, "NATIVE_BATCH", False):
        return time_queries_batched(engine, repeats, seed)
    return time_queries(engine, repeats, rng)


def time_updates(idx, n_base: int, ops: int, rng, weight_fn) -> float:
    """Mean seconds per update (insert+delete pairs, amortized).

    Device engines defer structural work into a delta buffer that is paid
    at the next sample; a settling query inside the timed region charges
    that flush/rebuild to the updates so the amortized cost is honest.
    An identical untimed dry-run cycle first compiles every shape the
    timed cycle will hit (inserts grow the slot array, so the settle
    shape after growth differs from the initial one), keeping one-time
    XLA compilation out of the measurement.
    """
    native = getattr(idx, "NATIVE_BATCH", False)
    if native:
        import jax

        for i in range(ops):
            idx.insert(("warm", i), float(weight_fn()))
        idx.query_batch(jax.random.key(1), 1)
        for i in range(ops):
            idx.delete(("warm", i))
        idx.query_batch(jax.random.key(2), 1)
    t0 = time.perf_counter()
    for i in range(ops):
        idx.insert(("bench", i), float(weight_fn()))
    for i in range(ops):
        idx.delete(("bench", i))
    if native:
        idx.query_batch(jax.random.key(0), 1)
    return (time.perf_counter() - t0) / (2 * ops)


def update_ops_for(engine, fast: int, slow: int) -> int:
    """Engines whose every update is an O(n) rebuild get the small budget."""
    return slow if getattr(engine, "UPDATE_REBUILDS", False) else fast


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.3f},{derived}"
