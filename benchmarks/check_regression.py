"""Perf-regression gate: diff a fresh benchmark run against history.

CI runs the container-scale smoke twice per change anyway (the committed
``benchmarks/results/bench_results.json`` is the history; the fresh run
is a scratch file) -- this module joins the two record lists on their
identity fields and fails when any latency metric regressed more than
``--threshold`` (default 2x, absorbing shared-runner noise).

Record identity = every non-metric field (fig, method, n, dist, c, ...);
metrics = numeric fields ending in ``_us`` plus ``recompiles`` (any
recompile growth under churn is a regression by definition -- that is
the invariant the SnapshotSpec layer enforces).  Records present on only
one side are reported but never fail the gate, so adding a scenario or
re-scoping history does not break CI.

Usage:
  python -m benchmarks.run --quick --only fig1,pipeline,churn --out /tmp/b.json
  python -m benchmarks.check_regression --current /tmp/b.json
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Tuple

#: timings under this are timer noise on shared runners; never gate on them
MIN_BASELINE_US = 0.5

#: measured outputs that identify NOTHING about a record -- excluded from
#: the join key.  Gated: *_us latencies and recompiles.  Ungated but
#: still non-identity: statistical/size outputs whose run-to-run noise
#: (or platform PRNG drift) would make the join spuriously miss.
_UNGATED_MEASUREMENTS = ("max_abs_error", "bytes", "coverage")


def _is_measurement(k: str) -> bool:
    return k.endswith("_us") or k == "recompiles" or k in _UNGATED_MEASUREMENTS


def _key(rec: dict) -> Tuple:
    return tuple(sorted(
        (k, v) for k, v in rec.items() if not _is_measurement(k)
    ))


def _metrics(rec: dict) -> Dict[str, float]:
    return {k: float(v) for k, v in rec.items()
            if k.endswith("_us") or k == "recompiles"}


def compare(baseline: List[dict], current: List[dict],
            threshold: float) -> Tuple[List[str], List[str]]:
    """Returns (regressions, notes); gate fails iff regressions != []."""
    base = {_key(r): r for r in baseline}
    regressions, notes = [], []
    matched = 0
    seen = set()
    for rec in current:
        k = _key(rec)
        seen.add(k)
        if k not in base:
            notes.append(f"no history for {dict(k)} (new scenario, skipped)")
            continue
        matched += 1
        ref = _metrics(base[k])
        for metric, now in _metrics(rec).items():
            then = ref.get(metric)
            if then is None:
                continue
            if metric == "recompiles":
                if now > then:
                    regressions.append(
                        f"{dict(k)}: recompiles {then:.0f} -> {now:.0f}")
                continue
            if then < MIN_BASELINE_US:
                continue
            if now > threshold * then:
                regressions.append(
                    f"{dict(k)}: {metric} {then:.2f}us -> {now:.2f}us "
                    f"({now / then:.2f}x > {threshold:.1f}x)")
    for k in base:
        if k not in seen:
            notes.append(
                f"baseline record {dict(k)} absent from this run -- "
                f"coverage shrank (not a failure, but check --only)")
    if matched == 0:
        notes.append("WARNING: zero records matched history -- gate is vacuous")
    return regressions, notes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline",
                    default="benchmarks/results/bench_results.json")
    ap.add_argument("--current", required=True)
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="fail when current > threshold * baseline")
    args = ap.parse_args()

    baseline = json.loads(Path(args.baseline).read_text())
    current = json.loads(Path(args.current).read_text())
    regressions, notes = compare(baseline, current, args.threshold)
    for n in notes:
        print(f"# {n}")
    if regressions:
        print(f"PERF REGRESSION ({len(regressions)} metric(s) > "
              f"{args.threshold:.1f}x baseline):")
        for r in regressions:
            print(f"  {r}")
        sys.exit(1)
    print(f"perf gate OK ({len(current)} current records, "
          f"{len(baseline)} in history)")


if __name__ == "__main__":
    main()
