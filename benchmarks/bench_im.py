"""Paper Sec 5 (Figures 5 & 6): dynamic IM running time + edge-update time."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.applications.im import (
    DynamicWCGraph,
    influence_maximization,
    synthetic_powerlaw_edges,
)

from .common import csv_row


def bench_im_runtime(n_nodes: int = 20_000, m_per_node: int = 4,
                     ks=(1, 10, 50), n_rr: int = 2000,
                     weight_dist: str = "exponential", seed: int = 0,
                     backends=("host-dips", "host-rodss", "host-brute")) -> List[dict]:
    """Fig 5: IM running time for different seed-set sizes k."""
    rows = []
    edges = synthetic_powerlaw_edges(n_nodes, m_per_node, weight_dist, seed)
    for backend in backends:
        g = DynamicWCGraph.from_edges(n_nodes, edges, backend=backend, seed=seed)
        for k in ks:
            seeds, cov, secs = influence_maximization(g, k, n_rr)
            rows.append({"fig": "fig5", "backend": backend, "k": k,
                         "n_rr": n_rr, "coverage": cov, "seconds": secs,
                         "dist": weight_dist})
            print(csv_row(f"fig5/{backend}/k{k}", secs * 1e6,
                          f"coverage={cov:.3f};n_rr={n_rr}"))
    return rows


def bench_im_updates(n_nodes: int = 20_000, m_per_node: int = 4,
                     n_updates: int = 2000, weight_dist: str = "exponential",
                     seed: int = 0,
                     backends=("host-dips", "host-rodss", "host-brute")) -> List[dict]:
    """Fig 6: edge insertion+deletion time into the sampling structures."""
    rows = []
    edges = synthetic_powerlaw_edges(n_nodes, m_per_node, weight_dist, seed)
    rng = np.random.default_rng(seed + 1)
    for backend in backends:
        g = DynamicWCGraph.from_edges(n_nodes, edges, backend=backend, seed=seed)
        rebuilds = any(getattr(e, "UPDATE_REBUILDS", False)
                       for e in g.in_index.values())
        ops = max(50, n_updates // 20) if rebuilds else n_updates
        picks = [edges[i] for i in rng.integers(0, len(edges), ops)]
        is_device = g.backend_kind == "device"
        touched = {v for _, v, _ in picks}
        if is_device:
            # warm up: first-ever query per engine jit-compiles its sample
            # program; the timed settle below then measures only the flush
            for v in touched:
                g.in_index[v].query(rng)
        t0 = time.perf_counter()
        for u, v, w in picks:
            g.delete_edge(u, v)
            g.insert_edge(u, v, w)
        if is_device:
            # settle each touched per-vertex engine so the deferred
            # delta-buffer flush is charged to the updates it serves
            for v in touched:
                g.in_index[v].query(rng)
        dt = (time.perf_counter() - t0) / (2 * ops)
        rows.append({"fig": "fig6", "backend": backend,
                     "update_us": dt * 1e6, "dist": weight_dist})
        print(csv_row(f"fig6/{backend}", dt * 1e6, f"dist={weight_dist}"))
    return rows
