"""Paper experiment reproductions: Figures 1-4 + Table 1 (Sec 4).

Default sizes are scaled for a single-core CI container; ``--full`` runs
paper-scale n.  Every function prints ``name,us_per_call,derived`` rows and
returns structured records for EXPERIMENTS.md generation.

Methods come from the ``repro.engine`` registry (see common.METHODS), so
host and device backends are benchmarked side by side: figs 1-2 cover the
whole registry, figs 3-4 default to the host engines (paper scale, n up
to 10M, would drown CPU-interpret device paths).
"""

from __future__ import annotations

import sys
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import max_abs_error
from repro.core.pps import PPSInstance
from repro.engine import available_engines

from .common import (
    DISTRIBUTIONS,
    METHODS,
    csv_row,
    make_items,
    time_engine_queries,
    time_updates,
    update_ops_for,
)

#: Theta(B*n) device paths (flat mask + CPU-interpret Pallas) pay a large
#: per-query constant off-accelerator; bound their repeat budgets so
#: fig1/fig2 stay container-friendly.  (jax-bucketed is output-sensitive
#: and needs no cap -- that asymmetry is the paper's point.)
_QUERY_REPEAT_CAP = {"pallas-mask": 20_000, "jax-flat": 20_000}


def _count_batched(engine, counts: Dict, todo: int, seed: int,
                   chunk: int = 1024) -> None:
    """Accumulate key counts for ``todo`` queries via query_batch."""
    import jax

    done = 0
    while done < todo:
        b = min(chunk, todo - done)
        ids, cnts = engine.query_batch(jax.random.key(seed + done), b)
        for ks in engine.decode_batch(ids, cnts):
            for k in ks:
                counts[k] = counts.get(k, 0) + 1
        done += b


# ---------------------------- Fig 1: correctness ------------------------------

def bench_correctness(n: int = 10_000, updates: int = 1000,
                      repeat_grid=(1_000, 10_000, 100_000),
                      dist: str = "lognormal", seed: int = 0) -> List[dict]:
    """Max |phat - p| vs query repeats after a 500-insert/500-delete churn."""
    rows = []
    rng = np.random.default_rng(seed)
    for name, ctor in METHODS.items():
        items = make_items(dist, n, seed)
        idx = ctor(dict(items), 1.0, seed)
        gen = DISTRIBUTIONS[dist]
        for i in range(updates // 2):
            idx.insert(("u", i), float(gen(rng, 1)[0]))
        for i in range(updates // 2):
            idx.delete(("u", i))
        counts: Dict = {}
        done = 0
        inst = PPSInstance(dict(items), c=1.0)
        cap = _QUERY_REPEAT_CAP.get(name, repeat_grid[-1])
        for target in repeat_grid:
            target = min(target, cap)
            if target <= done:
                continue
            if getattr(idx, "NATIVE_BATCH", False):
                _count_batched(idx, counts, target - done, seed + done)
            else:
                while done < target:
                    for k in idx.query(rng):
                        counts[k] = counts.get(k, 0) + 1
                    done += 1
            done = target
            err = max_abs_error(inst, counts, done)
            rows.append({"fig": "fig1", "method": name, "repeats": done,
                         "max_abs_error": err})
            print(csv_row(f"fig1/{name}/r{done}", 0.0, f"maxerr={err:.5f}"))
    return rows


# ------------------------ Fig 2: query/update tradeoff ---------------------------

def bench_tradeoff(n: int = 100_000, dist: str = "lognormal",
                   q_reps: int = 2000, seed: int = 0) -> List[dict]:
    rows = []
    rng = np.random.default_rng(seed)
    gen = DISTRIBUTIONS[dist]
    for name, ctor in METHODS.items():
        items = make_items(dist, n, seed)
        idx = ctor(dict(items), 1.0, seed)
        reps = min(q_reps, _QUERY_REPEAT_CAP.get(name, q_reps))
        tq = time_engine_queries(idx, reps, rng, seed)
        ops = update_ops_for(idx, fast=2000, slow=5)
        tu = time_updates(idx, n, ops, rng, lambda: gen(rng, 1)[0])
        rows.append({"fig": "fig2", "method": name, "n": n,
                     "query_us": tq * 1e6, "update_us": tu * 1e6})
        print(csv_row(f"fig2/{name}", tq * 1e6,
                      f"update_us={tu*1e6:.2f};n={n}"))
    return rows


# ------------------------ Fig 3 (+7-9): query time vs n ---------------------------

def bench_query(ns=(10_000, 100_000, 1_000_000), dists=("exponential", "lognormal"),
                cs=(1.0, 0.4), q_reps: int = 2000, seed: int = 0,
                methods: Optional[tuple] = None) -> List[dict]:
    if methods is None:
        methods = tuple(m for m in available_engines(kind="host")
                        if m != "host-brute")
    rows = []
    rng = np.random.default_rng(seed)
    for dist in dists:
        for c in cs:
            for n in ns:
                items = make_items(dist, n, seed)
                for name in methods:
                    idx = METHODS[name](dict(items), c, seed)
                    reps = min(q_reps, _QUERY_REPEAT_CAP.get(name, q_reps))
                    tq = time_engine_queries(idx, reps, rng, seed)
                    rows.append({"fig": "fig3", "method": name, "n": n,
                                 "dist": dist, "c": c, "query_us": tq * 1e6})
                    print(csv_row(f"fig3/{name}/{dist}/c{c}/n{n}", tq * 1e6))
    return rows


# ------------------------ Fig 4: update time vs n -----------------------------------

def bench_update(ns=(10_000, 100_000, 1_000_000), dist: str = "lognormal",
                 seed: int = 0,
                 methods: Optional[tuple] = None) -> List[dict]:
    if methods is None:
        methods = available_engines(kind="host")
    rows = []
    rng = np.random.default_rng(seed)
    gen = DISTRIBUTIONS[dist]
    for n in ns:
        items = make_items(dist, n, seed)
        for name in methods:
            idx = METHODS[name](dict(items), 1.0, seed)
            ops = update_ops_for(idx, fast=1000, slow=4)
            tu = time_updates(idx, n, ops, rng, lambda: gen(rng, 1)[0])
            rows.append({"fig": "fig4", "method": name, "n": n,
                         "dist": dist, "update_us": tu * 1e6})
            print(csv_row(f"fig4/{name}/n{n}", tu * 1e6))
    return rows


# ------------------------ Table 1: memory usage -----------------------------------

def _deep_bytes(obj, seen=None) -> int:
    import sys as _sys

    if seen is None:
        seen = set()
    oid = id(obj)
    if oid in seen:
        return 0
    seen.add(oid)
    size = _sys.getsizeof(obj, 0)
    if isinstance(obj, np.ndarray):
        return size + obj.nbytes
    if isinstance(obj, dict):
        size += sum(_deep_bytes(k, seen) + _deep_bytes(v, seen)
                    for k, v in obj.items())
    elif isinstance(obj, (list, tuple, set, frozenset)):
        size += sum(_deep_bytes(x, seen) for x in obj)
    elif hasattr(obj, "__dict__"):
        size += _deep_bytes(vars(obj), seen)
    elif hasattr(obj, "__slots__"):
        size += sum(_deep_bytes(getattr(obj, a), seen)
                    for a in obj.__slots__ if hasattr(obj, a))
    return size


def bench_memory(ns=(10_000, 100_000, 1_000_000), dist: str = "lognormal",
                 seed: int = 0) -> List[dict]:
    rows = []
    for n in ns:
        items = make_items(dist, n, seed)
        for name in ("host-dips", "host-rodss"):
            idx = METHODS[name](dict(items), 1.0, seed)
            # measure the underlying index, not the engine facade (the
            # wrapper's slot table + weight mirror is identical overhead
            # for every method and would compress the paper's Table 1 ratio)
            b = _deep_bytes(getattr(idx, "_impl", idx))
            rows.append({"fig": "table1", "method": name, "n": n, "bytes": b})
            print(csv_row(f"table1/{name}/n{n}", 0.0, f"MB={b/1e6:.2f}"))
    return rows
