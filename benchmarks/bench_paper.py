"""Paper experiment reproductions: Figures 1-4 + Table 1 (Sec 4).

Default sizes are scaled for a single-core CI container; ``--full`` runs
paper-scale n.  Every function prints ``name,us_per_call,derived`` rows and
returns structured records for EXPERIMENTS.md generation.
"""

from __future__ import annotations

import sys
import time
from typing import Dict, List

import numpy as np

from repro.core import max_abs_error
from repro.core.pps import PPSInstance

from .common import DISTRIBUTIONS, METHODS, csv_row, make_items, time_queries, time_updates


# ---------------------------- Fig 1: correctness ------------------------------

def bench_correctness(n: int = 10_000, updates: int = 1000,
                      repeat_grid=(1_000, 10_000, 100_000),
                      dist: str = "lognormal", seed: int = 0) -> List[dict]:
    """Max |phat - p| vs query repeats after a 500-insert/500-delete churn."""
    rows = []
    rng = np.random.default_rng(seed)
    for name, ctor in METHODS.items():
        items = make_items(dist, n, seed)
        idx = ctor(dict(items), 1.0, seed)
        gen = DISTRIBUTIONS[dist]
        for i in range(updates // 2):
            idx.insert(("u", i), float(gen(rng, 1)[0]))
        for i in range(updates // 2):
            idx.delete(("u", i))
        counts: Dict = {}
        done = 0
        inst = PPSInstance(dict(items), c=1.0)
        for target in repeat_grid:
            while done < target:
                for k in idx.query(rng):
                    counts[k] = counts.get(k, 0) + 1
                done += 1
            err = max_abs_error(inst, counts, done)
            rows.append({"fig": "fig1", "method": name, "repeats": done,
                         "max_abs_error": err})
            print(csv_row(f"fig1/{name}/r{done}", 0.0, f"maxerr={err:.5f}"))
    return rows


# ------------------------ Fig 2: query/update tradeoff ---------------------------

def bench_tradeoff(n: int = 100_000, dist: str = "lognormal",
                   q_reps: int = 2000, seed: int = 0) -> List[dict]:
    rows = []
    rng = np.random.default_rng(seed)
    gen = DISTRIBUTIONS[dist]
    for name, ctor in METHODS.items():
        items = make_items(dist, n, seed)
        idx = ctor(dict(items), 1.0, seed)
        tq = time_queries(idx, q_reps, rng)
        ops = 2000 if name in ("DIPS", "BruteForce") else 5
        tu = time_updates(idx, n, ops, rng, lambda: gen(rng, 1)[0])
        rows.append({"fig": "fig2", "method": name, "n": n,
                     "query_us": tq * 1e6, "update_us": tu * 1e6})
        print(csv_row(f"fig2/{name}", tq * 1e6,
                      f"update_us={tu*1e6:.2f};n={n}"))
    return rows


# ------------------------ Fig 3 (+7-9): query time vs n ---------------------------

def bench_query(ns=(10_000, 100_000, 1_000_000), dists=("exponential", "lognormal"),
                cs=(1.0, 0.4), q_reps: int = 2000, seed: int = 0,
                methods=("DIPS", "R-ODSS", "R-BSS", "R-HSS")) -> List[dict]:
    rows = []
    rng = np.random.default_rng(seed)
    for dist in dists:
        for c in cs:
            for n in ns:
                items = make_items(dist, n, seed)
                for name in methods:
                    idx = METHODS[name](dict(items), c, seed)
                    tq = time_queries(idx, q_reps, rng)
                    rows.append({"fig": "fig3", "method": name, "n": n,
                                 "dist": dist, "c": c, "query_us": tq * 1e6})
                    print(csv_row(f"fig3/{name}/{dist}/c{c}/n{n}", tq * 1e6))
    return rows


# ------------------------ Fig 4: update time vs n -----------------------------------

def bench_update(ns=(10_000, 100_000, 1_000_000), dist: str = "lognormal",
                 seed: int = 0,
                 methods=("DIPS", "R-ODSS", "R-BSS", "R-HSS", "BruteForce")
                 ) -> List[dict]:
    rows = []
    rng = np.random.default_rng(seed)
    gen = DISTRIBUTIONS[dist]
    for n in ns:
        items = make_items(dist, n, seed)
        for name in methods:
            idx = METHODS[name](dict(items), 1.0, seed)
            ops = 1000 if name in ("DIPS", "BruteForce") else 4
            tu = time_updates(idx, n, ops, rng, lambda: gen(rng, 1)[0])
            rows.append({"fig": "fig4", "method": name, "n": n,
                         "dist": dist, "update_us": tu * 1e6})
            print(csv_row(f"fig4/{name}/n{n}", tu * 1e6))
    return rows


# ------------------------ Table 1: memory usage -----------------------------------

def _deep_bytes(obj, seen=None) -> int:
    import sys as _sys

    if seen is None:
        seen = set()
    oid = id(obj)
    if oid in seen:
        return 0
    seen.add(oid)
    size = _sys.getsizeof(obj, 0)
    if isinstance(obj, np.ndarray):
        return size + obj.nbytes
    if isinstance(obj, dict):
        size += sum(_deep_bytes(k, seen) + _deep_bytes(v, seen)
                    for k, v in obj.items())
    elif isinstance(obj, (list, tuple, set, frozenset)):
        size += sum(_deep_bytes(x, seen) for x in obj)
    elif hasattr(obj, "__dict__"):
        size += _deep_bytes(vars(obj), seen)
    elif hasattr(obj, "__slots__"):
        size += sum(_deep_bytes(getattr(obj, a), seen)
                    for a in obj.__slots__ if hasattr(obj, a))
    return size


def bench_memory(ns=(10_000, 100_000, 1_000_000), dist: str = "lognormal",
                 seed: int = 0) -> List[dict]:
    rows = []
    for n in ns:
        items = make_items(dist, n, seed)
        for name in ("DIPS", "R-ODSS"):
            idx = METHODS[name](dict(items), 1.0, seed)
            b = _deep_bytes(idx)
            rows.append({"fig": "table1", "method": name, "n": n, "bytes": b})
            print(csv_row(f"table1/{name}/n{n}", 0.0, f"MB={b/1e6:.2f}"))
    return rows
