"""Paper experiment reproductions: Figures 1-4 + Table 1 (Sec 4).

Default sizes are scaled for a single-core CI container; ``--full`` runs
paper-scale n.  Every function prints ``name,us_per_call,derived`` rows and
returns structured records for EXPERIMENTS.md generation.

Methods come from the ``repro.engine`` registry (see common.METHODS), so
host and device backends are benchmarked side by side: figs 1-2 cover the
whole registry, figs 3-4 default to the host engines (paper scale, n up
to 10M, would drown CPU-interpret device paths).
"""

from __future__ import annotations

import sys
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import max_abs_error
from repro.core.pps import PPSInstance
from repro.engine import available_engines

from .common import (
    DISTRIBUTIONS,
    METHODS,
    csv_row,
    make_items,
    time_engine_queries,
    time_updates,
    update_ops_for,
)

#: Theta(B*n) device paths (flat mask + CPU-interpret Pallas) pay a large
#: per-query constant off-accelerator; bound their repeat budgets so
#: fig1/fig2 stay container-friendly.  (jax-bucketed is output-sensitive
#: and needs no cap -- that asymmetry is the paper's point.)
_QUERY_REPEAT_CAP = {"pallas-mask": 20_000, "jax-flat": 20_000}


def _count_batched(engine, counts: Dict, todo: int, seed: int,
                   chunk: int = 1024) -> None:
    """Accumulate key counts for ``todo`` queries via query_batch."""
    import jax

    done = 0
    while done < todo:
        b = min(chunk, todo - done)
        ids, cnts = engine.query_batch(jax.random.key(seed + done), b)
        for ks in engine.decode_batch(ids, cnts):
            for k in ks:
                counts[k] = counts.get(k, 0) + 1
        done += b


# --------------------- steady-state churn: recompiles + latency -----------------

def bench_churn(n: int = 20_000, rounds: int = 30, batch: int = 256,
                cap: int = 32, warmup_rounds: int = 2, seed: int = 0,
                methods: Optional[tuple] = None) -> List[dict]:
    """Interleaved insert/delete/change_w + samples against device engines:
    reports XLA recompiles after warmup (the new ``compile_cache_misses``
    counter) and post-warmup per-sample latency.

    This is THE scenario size-class padding (engine/spec.py) exists for:
    every round forces a snapshot rebuild, and without static shapes each
    rebuild would retrace ``bucketed_sample`` -- seconds of compile where
    DIPS pays microseconds.  A healthy run reports recompiles=0.
    """
    import jax

    if methods is None:
        methods = tuple(m for m in available_engines(kind="device"))
    rows = []
    rng = np.random.default_rng(seed)
    for name in methods:
        # Theta(B*n) paths off-accelerator get the small budget (same
        # rationale as _QUERY_REPEAT_CAP); the recompile count -- the
        # scenario's point -- is unaffected by the scale-down.
        flat_cost = name in _QUERY_REPEAT_CAP
        n_m = min(n, 2_000) if flat_cost else n
        batch_m = min(batch, 32) if flat_cost else batch
        rounds_m = min(rounds, 5) if flat_cost else rounds
        items = make_items("lognormal", n_m, seed)
        e = METHODS[name](dict(items), 1.0, seed)
        misses_at = lambda: getattr(e, "compile_cache_misses", 0)

        def round_trip(r: int) -> float:
            # the steady-state serving mix: one structural pair, a small
            # change_w batch, then one batched sample (timed)
            e.insert(("churn", r), float(DISTRIBUTIONS["lognormal"](rng, 1)[0]))
            e.delete(("churn", r))
            for i in rng.integers(0, n_m, 16):
                e.change_w(int(i), float(DISTRIBUTIONS["lognormal"](rng, 1)[0]))
            t0 = time.perf_counter()
            e.query_batch(jax.random.key(seed + r), batch_m, cap=cap)
            return time.perf_counter() - t0

        for r in range(warmup_rounds):
            round_trip(r)
        misses0 = misses_at()
        t_sample = [round_trip(warmup_rounds + r) for r in range(rounds_m)]
        recompiles = misses_at() - misses0
        us = float(np.mean(t_sample)) / batch_m * 1e6
        rows.append({"fig": "churn", "method": name, "n": n_m, "batch": batch_m,
                     "recompiles": recompiles, "sample_us": us})
        print(csv_row(f"churn/{name}/n{n_m}", us, f"recompiles={recompiles}"))
    return rows


# ---------------------------- Fig 1: correctness ------------------------------

def bench_correctness(n: int = 10_000, updates: int = 1000,
                      repeat_grid=(1_000, 10_000, 100_000),
                      dist: str = "lognormal", seed: int = 0) -> List[dict]:
    """Max |phat - p| vs query repeats after a 500-insert/500-delete churn."""
    rows = []
    rng = np.random.default_rng(seed)
    for name, ctor in METHODS.items():
        items = make_items(dist, n, seed)
        idx = ctor(dict(items), 1.0, seed)
        gen = DISTRIBUTIONS[dist]
        for i in range(updates // 2):
            idx.insert(("u", i), float(gen(rng, 1)[0]))
        for i in range(updates // 2):
            idx.delete(("u", i))
        counts: Dict = {}
        done = 0
        inst = PPSInstance(dict(items), c=1.0)
        cap = _QUERY_REPEAT_CAP.get(name, repeat_grid[-1])
        for target in repeat_grid:
            target = min(target, cap)
            if target <= done:
                continue
            if getattr(idx, "NATIVE_BATCH", False):
                _count_batched(idx, counts, target - done, seed + done)
            else:
                while done < target:
                    for k in idx.query(rng):
                        counts[k] = counts.get(k, 0) + 1
                    done += 1
            done = target
            err = max_abs_error(inst, counts, done)
            rows.append({"fig": "fig1", "method": name, "repeats": done,
                         "max_abs_error": err})
            print(csv_row(f"fig1/{name}/r{done}", 0.0, f"maxerr={err:.5f}"))
    return rows


# ------------------------ Fig 2: query/update tradeoff ---------------------------

def bench_tradeoff(n: int = 100_000, dist: str = "lognormal",
                   q_reps: int = 2000, seed: int = 0) -> List[dict]:
    rows = []
    rng = np.random.default_rng(seed)
    gen = DISTRIBUTIONS[dist]
    for name, ctor in METHODS.items():
        items = make_items(dist, n, seed)
        idx = ctor(dict(items), 1.0, seed)
        reps = min(q_reps, _QUERY_REPEAT_CAP.get(name, q_reps))
        tq = time_engine_queries(idx, reps, rng, seed)
        ops = update_ops_for(idx, fast=2000, slow=5)
        tu = time_updates(idx, n, ops, rng, lambda: gen(rng, 1)[0])
        rows.append({"fig": "fig2", "method": name, "n": n,
                     "query_us": tq * 1e6, "update_us": tu * 1e6})
        print(csv_row(f"fig2/{name}", tq * 1e6,
                      f"update_us={tu*1e6:.2f};n={n}"))
    return rows


# ------------------------ Fig 3 (+7-9): query time vs n ---------------------------

def bench_query(ns=(10_000, 100_000, 1_000_000), dists=("exponential", "lognormal"),
                cs=(1.0, 0.4), q_reps: int = 2000, seed: int = 0,
                methods: Optional[tuple] = None) -> List[dict]:
    if methods is None:
        methods = tuple(m for m in available_engines(kind="host")
                        if m != "host-brute")
    rows = []
    rng = np.random.default_rng(seed)
    for dist in dists:
        for c in cs:
            for n in ns:
                items = make_items(dist, n, seed)
                for name in methods:
                    idx = METHODS[name](dict(items), c, seed)
                    reps = min(q_reps, _QUERY_REPEAT_CAP.get(name, q_reps))
                    tq = time_engine_queries(idx, reps, rng, seed)
                    rows.append({"fig": "fig3", "method": name, "n": n,
                                 "dist": dist, "c": c, "query_us": tq * 1e6})
                    print(csv_row(f"fig3/{name}/{dist}/c{c}/n{n}", tq * 1e6))
    return rows


# ------------------------ Fig 4: update time vs n -----------------------------------

def bench_update(ns=(10_000, 100_000, 1_000_000), dist: str = "lognormal",
                 seed: int = 0,
                 methods: Optional[tuple] = None) -> List[dict]:
    if methods is None:
        methods = available_engines(kind="host")
    rows = []
    rng = np.random.default_rng(seed)
    gen = DISTRIBUTIONS[dist]
    for n in ns:
        items = make_items(dist, n, seed)
        for name in methods:
            idx = METHODS[name](dict(items), 1.0, seed)
            ops = update_ops_for(idx, fast=1000, slow=4)
            tu = time_updates(idx, n, ops, rng, lambda: gen(rng, 1)[0])
            rows.append({"fig": "fig4", "method": name, "n": n,
                         "dist": dist, "update_us": tu * 1e6})
            print(csv_row(f"fig4/{name}/n{n}", tu * 1e6))
    return rows


# ------------------------ Table 1: memory usage -----------------------------------

def _deep_bytes(obj, seen=None) -> int:
    import sys as _sys

    if seen is None:
        seen = set()
    oid = id(obj)
    if oid in seen:
        return 0
    seen.add(oid)
    size = _sys.getsizeof(obj, 0)
    if isinstance(obj, np.ndarray):
        return size + obj.nbytes
    if isinstance(obj, dict):
        size += sum(_deep_bytes(k, seen) + _deep_bytes(v, seen)
                    for k, v in obj.items())
    elif isinstance(obj, (list, tuple, set, frozenset)):
        size += sum(_deep_bytes(x, seen) for x in obj)
    elif hasattr(obj, "__dict__"):
        size += _deep_bytes(vars(obj), seen)
    elif hasattr(obj, "__slots__"):
        size += sum(_deep_bytes(getattr(obj, a), seen)
                    for a in obj.__slots__ if hasattr(obj, a))
    return size


def bench_memory(ns=(10_000, 100_000, 1_000_000), dist: str = "lognormal",
                 seed: int = 0) -> List[dict]:
    rows = []
    for n in ns:
        items = make_items(dist, n, seed)
        for name in ("host-dips", "host-rodss"):
            idx = METHODS[name](dict(items), 1.0, seed)
            # measure the underlying index, not the engine facade (the
            # wrapper's slot table + weight mirror is identical overhead
            # for every method and would compress the paper's Table 1 ratio)
            b = _deep_bytes(getattr(idx, "_impl", idx))
            rows.append({"fig": "table1", "method": name, "n": n, "bytes": b})
            print(csv_row(f"table1/{name}/n{n}", 0.0, f"MB={b/1e6:.2f}"))
    return rows
